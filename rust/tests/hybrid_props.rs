//! Properties of hybrid per-class backend dispatch (`--hybrid`):
//! plan-format round trips and validation, byte-compatibility of the
//! default path, worker-count bit-identity, the never-worse guarantee
//! against the pure-tuned compile, and the warm prune receipt in the
//! TuningDb's handlib namespace.

use ago::coordinator::plan::{self, LoadedPlan};
use ago::coordinator::{
    compile, compile_with_db, Backend, CompileConfig, TuningDb,
    HANDLIB_VARIANT,
};
use ago::device::DeviceProfile;
use ago::models::{build, InputShape, ModelId};
use ago::util::json::Json;

fn cfg(budget: usize, workers: usize) -> CompileConfig {
    CompileConfig {
        budget,
        workers,
        ..CompileConfig::new(DeviceProfile::kirin990())
    }
}

fn hybrid(budget: usize, workers: usize) -> CompileConfig {
    CompileConfig { hybrid: true, ..cfg(budget, workers) }
}

fn plan_text(m: &ago::coordinator::CompiledModel, name: &str) -> String {
    plan::to_json(m, name, "kirin990").pretty()
}

#[test]
fn hybrid_plan_roundtrips_and_tags_every_subgraph() {
    let g = build(ModelId::Sqn, InputShape::Small);
    let m = compile(&g, &hybrid(400, 2));
    let bks = m.backends.as_ref().expect("--hybrid tags the plan");
    assert_eq!(bks.len(), m.partition.n_groups);
    let text = plan_text(&m, "sqn");
    assert!(text.contains("\"backends\""));
    assert!(text.contains("\"hybrid\""));
    let back = plan::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.backends.as_ref(), Some(bks));
    // loaded_to_json drops the compile-only `hybrid` counters but keeps
    // the tags, and reaches a fixed point on the first serialization
    let once = plan::loaded_to_json(&back).pretty();
    assert!(once.contains("\"backends\""));
    assert!(!once.contains("\"hybrid\""));
    let twice = plan::loaded_to_json(
        &plan::from_json(&Json::parse(&once).unwrap()).unwrap(),
    )
    .pretty();
    assert_eq!(once, twice, "hybrid plan round trip not byte-stable");
}

#[test]
fn rejects_bad_backend_tags() {
    let sched = r#"[[{"ops": [0], "kind": "simple", "tile": [1, 1, 1]}]]"#;
    // wrong length
    assert!(plan::from_json(
        &Json::parse(&format!(
            r#"{{"assign": [0], "schedules": {sched},
                "subgraph_latency_s": [0.001],
                "backends": ["tuned", "handlib"]}}"#
        ))
        .unwrap()
    )
    .is_err());
    // unknown backend name
    assert!(plan::from_json(
        &Json::parse(&format!(
            r#"{{"assign": [0], "schedules": {sched},
                "subgraph_latency_s": [0.001],
                "backends": ["cuda"]}}"#
        ))
        .unwrap()
    )
    .is_err());
    // not an array
    assert!(plan::from_json(
        &Json::parse(&format!(
            r#"{{"assign": [0], "schedules": {sched},
                "subgraph_latency_s": [0.001],
                "backends": "handlib"}}"#
        ))
        .unwrap()
    )
    .is_err());
    // valid tags parse
    let ok: LoadedPlan = plan::from_json(
        &Json::parse(&format!(
            r#"{{"assign": [0], "schedules": {sched},
                "subgraph_latency_s": [0.001],
                "backends": ["handlib"]}}"#
        ))
        .unwrap(),
    )
    .unwrap();
    assert_eq!(ok.backends, Some(vec![Backend::Handlib]));
}

#[test]
fn hybrid_off_is_byte_identical_to_legacy() {
    // the flag default must keep every existing plan and db byte: a
    // non-hybrid compile after this PR == a non-hybrid compile before it
    let g = build(ModelId::Mbn, InputShape::Small);
    let mk = |hybrid_on: bool| {
        let c = CompileConfig { hybrid: hybrid_on, ..cfg(400, 2) };
        let mut db = TuningDb::new();
        let m = compile_with_db(&g, &c, &mut db);
        (plan_text(&m, "mbn"), db.to_json().pretty())
    };
    let (off_plan, off_db) = mk(false);
    assert!(!off_plan.contains("backends"));
    assert!(!off_db.contains(HANDLIB_VARIANT));
    // and identical across repeated runs (the golden-bytes property the
    // emission gating protects)
    let (again_plan, again_db) = mk(false);
    assert_eq!(off_plan, again_plan);
    assert_eq!(off_db, again_db);
    // hybrid ON must not change the plan's tuned content where the
    // tuned backend wins everywhere — but whatever it decides, the OFF
    // path's bytes never move; this is the compatibility contract
    let (on_plan, on_db) = mk(true);
    assert!(on_plan.contains("\"backends\""));
    assert!(on_db.contains(HANDLIB_VARIANT) || !on_plan.contains("handlib"));
}

#[test]
fn hybrid_bytes_are_worker_count_invariant() {
    // --hybrid adds pricing (library + reference) on the sequential
    // mode-decision path; plan AND db bytes must still be identical at
    // any worker count
    let g = build(ModelId::Sqn, InputShape::Small);
    let mk = |workers: usize| {
        let mut db = TuningDb::new();
        let m = compile_with_db(&g, &hybrid(500, workers), &mut db);
        (plan_text(&m, "sqn"), db.to_json().pretty())
    };
    let (p1, d1) = mk(1);
    let (p4, d4) = mk(4);
    let (p8, d8) = mk(8);
    assert_eq!(p1, p4, "hybrid plan bytes depend on worker count");
    assert_eq!(p1, p8, "hybrid plan bytes depend on worker count");
    assert_eq!(d1, d4, "hybrid db bytes depend on worker count");
    assert_eq!(d1, d8, "hybrid db bytes depend on worker count");
}

#[test]
fn hybrid_is_never_worse_than_pure_tuned_on_the_zoo() {
    // the Select-margin displacement discipline: per model, the hybrid
    // plan's predicted latency can only improve on the pure-tuned plan
    // (modulo pricing noise — none exists, both arms share the cost
    // model, so the comparison is exact)
    for model in ModelId::all() {
        let g = build(model, InputShape::Small);
        let tuned = compile(&g, &cfg(400, 2));
        let hyb = compile(&g, &hybrid(400, 2));
        assert!(
            hyb.total_latency <= tuned.total_latency,
            "{}: hybrid {} > tuned {}",
            model.name(),
            hyb.total_latency,
            tuned.total_latency
        );
        // provenance is consistent: handlib classes are counted iff
        // some subgraph carries the tag
        let tagged = hyb
            .backends
            .as_ref()
            .unwrap()
            .iter()
            .filter(|&&b| b == Backend::Handlib)
            .count();
        assert_eq!(tagged > 0, hyb.handlib_classes > 0, "{}", model.name());
    }
}

#[test]
fn handlib_receipts_warm_start_and_prune_later_compiles() {
    let g = build(ModelId::Mbn, InputShape::Small);
    let mut db = TuningDb::new();
    let first = compile_with_db(&g, &hybrid(800, 2), &mut db);
    // every dispatched class leaves a receipt in the handlib namespace
    // (Mbn's classes are unambiguous — the warm-compile tests pin that)
    let handlib_entries = db
        .entries()
        .filter(|e| e.variant == HANDLIB_VARIANT)
        .count();
    assert_eq!(
        handlib_entries > 0,
        first.handlib_classes > 0,
        "handlib namespace must mirror dispatched classes"
    );
    // a cold default compile has no seed to prune against: the flag can
    // only displace via the Select comparison, never skip FullTune
    assert_eq!(first.saved_evals, 0);
    // warm identical recompile decides identically, searches nothing,
    // and moves no db bytes
    let before = db.to_json().pretty();
    let second = compile_with_db(&g, &hybrid(800, 2), &mut db);
    assert_eq!(first.handlib_classes, second.handlib_classes);
    assert_eq!(first.backends, second.backends);
    assert_eq!(first.total_latency.to_bits(), second.total_latency.to_bits());
    assert_eq!(second.tuned_tasks, 0, "warm hybrid recompile re-searched");
    assert_eq!(before, db.to_json().pretty());
    // a handlib receipt WITHOUT a tuned sibling is the pruned-class
    // marker: seed a fresh db with only the handlib namespace and the
    // compiler must adopt those classes outright — no search, budget
    // reported as saved
    if first.handlib_classes > 0 {
        let mut lib_only = TuningDb::new();
        for e in db.entries().filter(|e| e.variant == HANDLIB_VARIANT) {
            lib_only.record(e.clone());
        }
        let third = compile_with_db(&g, &hybrid(800, 2), &mut lib_only);
        assert_eq!(third.handlib_classes, first.handlib_classes);
        assert!(third.saved_evals > 0, "adopted classes must report savings");
        assert_eq!(
            third.tuned_tasks,
            third.n_classes - third.handlib_classes,
            "exactly the non-library classes get searched"
        );
        assert_eq!(third.backends, first.backends);
    }
}

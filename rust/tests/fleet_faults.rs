//! Crash/corruption fault injection for the sharded TuningDb, and the
//! incremental-recompile contract (PR 8):
//!
//! - a torn (truncated) shard, a wrong-version shard, a mis-labeled
//!   shard, and a coverage-invalid shard each surface as a
//!   [`ShardFault`] naming the shard file — while every healthy shard
//!   still loads; `quarantine` moves the evidence aside so the next
//!   load is clean
//! - an incremental recompile of an unmodified model retunes zero
//!   classes and reproduces the previous plan's durable content
//!   byte-for-byte
//! - a one-block edit retunes exactly the classes whose fingerprint
//!   the edit changed (computed independently from the stage layer),
//!   and the spliced plan is byte-identical to a cold full recompile
//!   against the same db

use std::path::Path;

use ago::coordinator::{
    compile_with_db, incremental_recompile, plan, stages, CompileConfig,
    DbEntry, ShardStore, TuningDb,
};
use ago::device::DeviceProfile;
use ago::graph::OpKind;
use ago::models::{build, InputShape, ModelId};
use ago::tuner::schedule::{FusionGroup, GroupKind, Layout, Schedule, Tile};

/// A valid synthetic entry: one group covering `0..n_ops`.
fn entry(fp: u64, latency: f64) -> DbEntry {
    let n_ops = 1 + (fp % 3) as usize;
    let schedule = Schedule {
        groups: vec![FusionGroup {
            ops: (0..n_ops).collect(),
            kind: GroupKind::Simple,
            tile: Tile { th: 4, tw: 4, tc: 8 },
            vec: 4,
            unroll: 2,
            threads: 2,
            layout: Layout::Nhwc,
        }],
    };
    let features = ago::costmodel::ClassFeatures::backfill(&schedule, n_ops);
    DbEntry {
        device: "kirin990".to_string(),
        variant: "ago".to_string(),
        fingerprint: fp,
        n_ops,
        schedule,
        latency,
        evals: 7,
        features,
    }
}

/// Seed a K=4 store with two entries per shard (top fingerprint byte
/// 0/64/128/192 maps to shard 0/1/2/3).
fn seeded_store(dir: &Path) -> (ShardStore, TuningDb) {
    let store = ShardStore::new(dir, 4);
    let mut db = TuningDb::new();
    for (si, b) in [0u64, 64, 128, 192].into_iter().enumerate() {
        for i in 1..3u64 {
            db.record(entry(
                (b << 56) | i,
                1e-3 + si as f64 * 1e-5 + i as f64 * 1e-7,
            ));
        }
    }
    store.save(&db).unwrap();
    (store, db)
}

/// The db restricted to top-bytes NOT in `dropped`.
fn without(db: &TuningDb, dropped: &[u64]) -> TuningDb {
    let mut out = TuningDb::new();
    for e in db.entries() {
        if !dropped.contains(&(e.fingerprint >> 56)) {
            out.record(e.clone());
        }
    }
    out
}

#[test]
fn torn_shard_is_quarantined_and_the_rest_load() {
    let dir = std::env::temp_dir().join("ago_fleet_faults_torn");
    std::fs::remove_dir_all(&dir).ok();
    let (store, db) = seeded_store(&dir);
    // tear shard 1 mid-write (what a crash before the atomic rename
    // could never produce — but a full disk, a kill -9 on a pre-atomic
    // writer, or a copy truncation can)
    let victim = store.shard_path(1);
    let text = std::fs::read_to_string(&victim).unwrap();
    std::fs::write(&victim, &text[..text.len() / 2]).unwrap();
    let (merged, faults) = store.load_merged();
    assert_eq!(faults.len(), 1, "{faults:?}");
    assert!(
        faults[0].path.contains("shard-001-of-004"),
        "fault must name the shard file: {}",
        faults[0].path
    );
    assert!(!faults[0].reason.is_empty());
    let expect = without(&db, &[64]);
    assert_eq!(
        merged.to_json().pretty(),
        expect.to_json().pretty(),
        "healthy shards must load despite the torn one"
    );
    // quarantine moves the evidence aside; the next load is clean
    let moved = store.quarantine(&faults);
    assert_eq!(moved.len(), 1);
    assert!(moved[0].contains("quarantined"), "{}", moved[0]);
    assert!(!victim.exists(), "torn shard still in place");
    assert!(Path::new(&moved[0]).exists(), "evidence deleted, not moved");
    let (merged2, faults2) = store.load_merged();
    assert!(faults2.is_empty(), "{faults2:?}");
    assert_eq!(merged2.to_json().pretty(), expect.to_json().pretty());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn untrusted_shards_fault_with_named_diagnostics() {
    let dir = std::env::temp_dir().join("ago_fleet_faults_untrusted");
    std::fs::remove_dir_all(&dir).ok();
    let (store, db) = seeded_store(&dir);
    // shard 0: wrong db version
    std::fs::write(
        store.shard_path(0),
        r#"{"version": 1, "shard": 0, "of": 4, "entries": []}"#,
    )
    .unwrap();
    // shard 2: header does not match the file name
    std::fs::write(
        store.shard_path(2),
        r#"{"version": 2, "shard": 3, "of": 4, "entries": []}"#,
    )
    .unwrap();
    // shard 3: coverage-invalid entry (claims far more ops than its
    // schedule covers) — surgical edit of the healthy file
    let text = std::fs::read_to_string(store.shard_path(3)).unwrap();
    assert!(text.contains("\"n_ops\": "), "unexpected shard layout");
    std::fs::write(
        store.shard_path(3),
        text.replacen("\"n_ops\": ", "\"n_ops\": 9", 1),
    )
    .unwrap();
    let (merged, faults) = store.load_merged();
    // faults arrive in file-name order: 000, 002, 003
    assert_eq!(faults.len(), 3, "{faults:?}");
    assert!(faults[0].path.contains("shard-000-of-004"));
    assert!(
        faults[0].reason.contains("version"),
        "wrong-version reason: {}",
        faults[0].reason
    );
    assert!(faults[1].path.contains("shard-002-of-004"));
    assert!(
        faults[1].reason.contains("does not match file name"),
        "mis-label reason: {}",
        faults[1].reason
    );
    assert!(faults[2].path.contains("shard-003-of-004"));
    assert!(
        faults[2].reason.contains("cover"),
        "coverage reason: {}",
        faults[2].reason
    );
    // only the untouched shard 1 contributes entries
    let expect = without(&db, &[0, 128, 192]);
    assert_eq!(merged.to_json().pretty(), expect.to_json().pretty());
    // quarantining all three leaves a clean store
    let moved = store.quarantine(&faults);
    assert_eq!(moved.len(), 3);
    let (merged2, faults2) = store.load_merged();
    assert!(faults2.is_empty(), "{faults2:?}");
    assert_eq!(merged2.to_json().pretty(), expect.to_json().pretty());
    std::fs::remove_dir_all(&dir).ok();
}

fn cfg() -> CompileConfig {
    CompileConfig {
        budget: 300,
        workers: 2,
        ..CompileConfig::new(DeviceProfile::kirin990())
    }
}

#[test]
fn incremental_of_unmodified_model_retunes_zero_and_is_identical() {
    let g = build(ModelId::Sqn, InputShape::Small);
    let base = cfg();
    let mut db = TuningDb::new();
    let m0 = compile_with_db(&g, &base, &mut db);
    let path = std::env::temp_dir().join("ago_fleet_faults_sqn.plan.json");
    let pstr = path.to_str().unwrap();
    plan::save(&m0, "SQN", "kirin990", pstr).unwrap();
    let prev = plan::load(pstr).unwrap();
    let out = incremental_recompile(&g, &base, &mut db, &prev);
    assert_eq!(out.report.retuned, 0, "unmodified model retuned classes");
    assert_eq!(out.report.spliced, m0.n_classes, "every class must splice");
    assert_eq!(out.report.changed_subgraphs, 0);
    assert!(out.report.identical, "unmodified model must be identical");
    // the durable plan content is reproduced byte-for-byte (provenance
    // fields like tuned_tasks legitimately differ between the original
    // and the warm recompile; they do not survive a load)
    let lp = plan::from_json(&out.plan).unwrap();
    assert_eq!(
        plan::loaded_to_json(&lp).pretty(),
        plan::loaded_to_json(&prev).pretty(),
        "recompile drifted from the previous plan"
    );
    std::fs::remove_file(pstr).ok();
}

#[test]
fn one_block_edit_retunes_exactly_the_new_classes() {
    let base = cfg();
    let g = build(ModelId::Mbn, InputShape::Small);
    let mut db = TuningDb::new();
    let m0 = compile_with_db(&g, &base, &mut db);
    let path = std::env::temp_dir().join("ago_fleet_faults_mbn.plan.json");
    let pstr = path.to_str().unwrap();
    plan::save(&m0, "MBN", "kirin990", pstr).unwrap();
    let prev = plan::load(pstr).unwrap();
    // one-block edit: a pointwise conv becomes a 3x3 Conv2d — still
    // shape-preserving at stride 1, but a different op kind with 9x the
    // work, so exactly the classes whose subgraph contains this node
    // get a new fingerprint (and a genuinely different cost surface —
    // a 1x1 conv would price identically to the pointwise op and could
    // tune to the very same schedule)
    let mut g2 = build(ModelId::Mbn, InputShape::Small);
    let idx = g2
        .nodes
        .iter()
        .position(|n| matches!(n.kind, OpKind::Pointwise))
        .expect("MBN has a pointwise op");
    g2.nodes[idx].kind = OpKind::Conv2d { kh: 3, kw: 3, stride: 1 };
    let db_before = db.clone();
    let out = incremental_recompile(&g2, &base, &mut db, &prev);
    // the expected retune set, derived independently through the stage
    // layer: classes of the edited graph whose representative
    // fingerprint is absent from the pre-edit db (ambiguous classes
    // always retune)
    let ps = stages::partition_stage(&g2, out.model.partition.clone());
    let ds = stages::dedup_stage(&g2, &ps, base.budget);
    let expected = ds
        .classes
        .iter()
        .filter(|c| {
            let cf = ps.canon[c.rep].as_ref().expect("non-empty subgraph");
            ds.ambiguous.contains(&cf.fingerprint)
                || db_before
                    .lookup("kirin990", base.variant.tag(), cf.fingerprint)
                    .is_none()
        })
        .count();
    assert!(expected >= 1, "the edit did not change any fingerprint");
    assert_eq!(
        out.report.retuned, expected,
        "retuned classes != classes with new fingerprints"
    );
    assert_eq!(out.report.spliced, out.model.n_classes - expected);
    assert!(
        out.report.spliced > 0,
        "untouched classes must splice from the db, not retune"
    );
    assert!(!out.report.identical);
    // the spliced plan is byte-identical to a cold full recompile
    // against the same db — same code path, but pinned, not assumed
    let mut db_cold = db_before.clone();
    let cold = compile_with_db(&g2, &base, &mut db_cold);
    assert_eq!(
        plan::to_json(&cold, "MBN", "kirin990").pretty(),
        out.plan.pretty(),
        "incremental and cold recompile diverged"
    );
    std::fs::remove_file(pstr).ok();
}

//! End-to-end runtime integration: real PJRT execution of the AOT
//! artifact catalog — fused plans vs unfused chains, numerics equality,
//! and the MobileNet-ish block pipeline the E2E example drives.

use ago::runtime::{Engine, TensorData};
use ago::util::Rng;

/// `None` (with a visible skip notice) when the AOT artifact catalog has
/// not been generated — the tier-1 gate (`cargo test -q`) must pass on a
/// fresh checkout; run `make artifacts` to enable these tests.
fn engine() -> Option<Engine> {
    let dir = ago::runtime::catalog_or_skip(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/artifacts"
    ))?;
    Some(Engine::new(dir).expect("engine"))
}

fn max_abs_diff(a: &TensorData, b: &TensorData) -> f32 {
    assert_eq!(a.shape, b.shape);
    a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Every fused pw->dw artifact in the catalog must equal its unfused
/// chain, executed for real.
#[test]
fn all_fused_pw_dw_match_unfused_chains() {
    let Some(mut e) = engine() else { return };
    let mut rng = Rng::new(11);
    // (fused, pw, dw) triples present in the catalog
    let stages = [
        ("fused_pw_dw_n1h28w28i16a32b32", "pw_n1h28w28i16o32",
         "dw3_n1h28w28c32", [1usize, 28, 28, 16], 16usize, 32usize),
        ("fused_pw_dw_n1h14w14i24a48b48", "pw_n1h14w14i24o48",
         "dw3_n1h14w14c48", [1, 14, 14, 24], 24, 48),
        ("fused_pw_dw_n1h7w7i32a64b64", "pw_n1h7w7i32o64",
         "dw3_n1h7w7c64", [1, 7, 7, 32], 32, 64),
    ];
    for (fused, pw, dw, xshape, ci, co) in stages {
        let x = TensorData::random(&xshape, &mut rng);
        let w1 = TensorData::random(&[ci, co], &mut rng);
        let b1 = TensorData::random(&[co], &mut rng);
        let w2 = TensorData::random(&[3, 3, 1, co], &mut rng);
        let b2 = TensorData::random(&[co], &mut rng);
        let f = e
            .execute(fused, &[x.clone(), w1.clone(), b1.clone(),
                              w2.clone(), b2.clone()])
            .unwrap_or_else(|err| panic!("{fused}: {err:#}"))
            .remove(0);
        let mid = e.execute(pw, &[x, w1, b1]).unwrap().remove(0);
        let u = e.execute(dw, &[mid, w2, b2]).unwrap().remove(0);
        let d = max_abs_diff(&f, &u);
        assert!(d < 2e-3, "{fused}: max diff {d}");
    }
}

/// The composite MobileNet block artifact equals the four-artifact
/// unfused chain (pw -> dw -> pw-linear -> residual add).
#[test]
fn mbn_block_fused_matches_unfused_pipeline() {
    let Some(mut e) = engine() else { return };
    let mut rng = Rng::new(12);
    let (h, c, m) = (28usize, 16usize, 32usize);
    let x = TensorData::random(&[1, h, h, c], &mut rng);
    let w1 = TensorData::random(&[c, m], &mut rng);
    let b1 = TensorData::random(&[m], &mut rng);
    let w2 = TensorData::random(&[3, 3, 1, m], &mut rng);
    let b2 = TensorData::random(&[m], &mut rng);
    let w3 = TensorData::random(&[m, c], &mut rng);
    let b3 = TensorData::random(&[c], &mut rng);
    let fused = e
        .execute(
            "mbnblk_fused_n1h28w28c16e2",
            &[x.clone(), w1.clone(), b1.clone(), w2.clone(), b2.clone(),
              w3.clone(), b3.clone()],
        )
        .unwrap()
        .remove(0);
    let a = e
        .execute("pw_n1h28w28i16o32", &[x.clone(), w1, b1])
        .unwrap()
        .remove(0);
    let b = e.execute("dw3_n1h28w28c32", &[a, w2, b2]).unwrap().remove(0);
    let c_ = e
        .execute("pw_n1h28w28i32o16", &[b, w3, b3])
        .unwrap()
        .remove(0);
    let out = e
        .execute("add_n1h28w28c16", &[c_, x])
        .unwrap()
        .remove(0);
    let d = max_abs_diff(&fused, &out);
    assert!(d < 2e-3, "mbn block: max diff {d}");
}

/// Fused ffn (mm->gelu->mm) equals the two-matmul chain.
#[test]
fn fused_ffn_matches_chain() {
    let Some(mut e) = engine() else { return };
    let mut rng = Rng::new(13);
    let x = TensorData::random(&[128, 128], &mut rng);
    let w1 = TensorData::random(&[128, 512], &mut rng);
    let b1 = TensorData::random(&[512], &mut rng);
    let w2 = TensorData::random(&[512, 128], &mut rng);
    let b2 = TensorData::random(&[128], &mut rng);
    let fused = e
        .execute("fused_mm_mm_m128k128a512b128",
                 &[x.clone(), w1.clone(), b1.clone(), w2.clone(),
                   b2.clone()])
        .unwrap()
        .remove(0);
    let mid = e
        .execute("mm_m128k128n512_gelu", &[x, w1, b1])
        .unwrap()
        .remove(0);
    let out = e
        .execute("mm_m128k512n128_none", &[mid, w2, b2])
        .unwrap()
        .remove(0);
    let d = max_abs_diff(&fused, &out);
    assert!(d < 5e-2, "ffn: max diff {d}"); // gelu + 512-wide reductions
}

/// Batched request serving: repeated execution is stable and the
/// executable cache keeps compilation out of the loop.
#[test]
fn repeated_requests_are_stable() {
    let Some(mut e) = engine() else { return };
    let mut rng = Rng::new(14);
    let x = TensorData::random(&[1, 14, 14, 32], &mut rng);
    let names = vec![
        "dw3_n1h14w14c32".to_string(),
        "pw_n1h14w14i32o64".to_string(),
    ];
    let (first, _) = e.run_chain(&names, x.clone(), 99).unwrap();
    for _ in 0..5 {
        let (again, _) = e.run_chain(&names, x.clone(), 99).unwrap();
        assert_eq!(first.data, again.data, "non-deterministic run");
    }
    assert_eq!(e.compiled_count(), 2);
}

/// Fig. 13 shapes: all four two-complex-op fused artifacts execute at
/// batch 1 and 4.
#[test]
fn fig13_artifacts_execute() {
    let Some(mut e) = engine() else { return };
    let mut rng = Rng::new(15);
    for b in [1usize, 4] {
        let cases: [(String, Vec<Vec<usize>>); 4] = [
            (format!("fused_dw_dw_n{b}h14w14i32a32b32"),
             vec![vec![b, 14, 14, 32], vec![3, 3, 1, 32], vec![32],
                  vec![3, 3, 1, 32], vec![32]]),
            (format!("fused_dw_pw_n{b}h14w14i32a32b64"),
             vec![vec![b, 14, 14, 32], vec![3, 3, 1, 32], vec![32],
                  vec![32, 64], vec![64]]),
            (format!("fused_pw_dw_n{b}h14w14i32a64b64"),
             vec![vec![b, 14, 14, 32], vec![32, 64], vec![64],
                  vec![3, 3, 1, 64], vec![64]]),
            (format!("fused_pw_pw_n{b}h14w14i32a64b32"),
             vec![vec![b, 14, 14, 32], vec![32, 64], vec![64],
                  vec![64, 32], vec![32]]),
        ];
        for (name, shapes) in cases {
            let inputs: Vec<TensorData> = shapes
                .iter()
                .map(|s| TensorData::random(s, &mut rng))
                .collect();
            let out = e
                .execute(&name, &inputs)
                .unwrap_or_else(|err| panic!("{name}: {err:#}"));
            assert_eq!(out[0].shape[0], b);
        }
    }
}

//! Property tests for the fused micro-kernel layer (`ago::kernels` +
//! fused pricing):
//!
//! 1. the pattern taxonomy is TOTAL over the seed zoo: every fusion
//!    group of every model classifies to exactly one pattern;
//! 2. fused pricing DOMINATES per-op-pass pricing pointwise (never
//!    worse on any schedule), and `fused = false` reproduces the legacy
//!    price to the bit;
//! 3. a fused [`PricingContext`] keeps `tune_parallel` bit-identical
//!    across 1/4/8 workers, and a fused compile's plan bytes are
//!    independent of `--workers` while round-tripping byte-exactly
//!    through the loaded form;
//! 4. a warm-seeded tune never returns a schedule priced worse than the
//!    seed it was given (the probe-seeding satellite's contract).

use ago::coordinator::{compile_with_db, plan, CompileConfig, TuningDb};
use ago::costmodel::{
    group_latency, group_latency_fused, schedule_latency,
    schedule_latency_fused, MemoCache, PricingContext,
};
use ago::device::DeviceProfile;
use ago::ensure;
use ago::graph::{Graph, OpKind, Shape, Subgraph};
use ago::kernels::{classify_group, classify_ops, count_patterns};
use ago::models::{build, InputShape, ModelId};
use ago::partition::{cluster, ClusterConfig};
use ago::tuner::schedule::SubgraphView;
use ago::tuner::search::{random_schedule, tune_parallel, SearchConfig};
use ago::util::propkit::forall;
use ago::util::{Json, Rng, ThreadPool};

/// Random chain of streaming/reduction/complex ops — the same shape of
/// generator `costmodel_props` uses, here biased to include reduction
/// ops so all four patterns appear across cases.
fn chain_graph(rng: &mut Rng) -> (Graph, SubgraphView) {
    let mut g = Graph::new("chain");
    let hw = *rng.choose(&[7usize, 14, 28]);
    let c = *rng.choose(&[8usize, 16, 32]);
    let s = Shape::nhwc(1, hw, hw, c);
    let n = rng.range(3, 11);
    let mut prev: Option<usize> = None;
    for i in 0..n {
        let kind = match rng.range(0, 6) {
            0 => OpKind::Pointwise,
            1 => OpKind::Depthwise { kh: 3, kw: 3, stride: 1 },
            2 => OpKind::BiasAdd,
            3 => OpKind::ReLU,
            4 => OpKind::Softmax,
            _ => OpKind::Add,
        };
        let inputs: Vec<usize> = prev.into_iter().collect();
        let id = g.add(kind, &format!("n{i}"), s.clone(), c, &inputs);
        prev = Some(id);
    }
    let nodes: Vec<usize> = (0..g.len()).collect();
    let view = SubgraphView::new(&g, &Subgraph { id: 0, nodes });
    (g, view)
}

/// Taxonomy totality over the whole seed zoo: every subgraph's op
/// inventory and every group of a random schedule classify to exactly
/// one of the four patterns, and the counts tile the group set.
#[test]
fn every_seed_zoo_group_classifies_to_exactly_one_pattern() {
    let mut rng = Rng::new(0xC1A5);
    for m in ModelId::all() {
        let g = build(m, InputShape::Small);
        let p = cluster(&g, ClusterConfig::adaptive(&g));
        let mut schedules = Vec::new();
        let mut n_groups = 0usize;
        for view in SubgraphView::all(&g, &p) {
            if view.is_empty() {
                continue;
            }
            // inventory classification is total per subgraph
            let pat = classify_ops(&g, &view.order);
            assert_eq!(ago::kernels::ALL[pat.index()], pat, "{}", m.name());
            let s = random_schedule(&g, &view, &mut rng, true);
            for grp in &s.groups {
                // exactly one pattern: classify is a function, and the
                // pattern self-indexes into the canonical order
                let gp = classify_group(&g, grp);
                assert_eq!(ago::kernels::ALL[gp.index()], gp, "{}", m.name());
                n_groups += 1;
            }
            schedules.push(s);
        }
        let counts = count_patterns(&g, &schedules);
        assert_eq!(
            counts.iter().sum::<usize>(),
            n_groups,
            "{}: counts {:?} do not tile {} groups",
            m.name(),
            counts,
            n_groups
        );
    }
}

/// Fused pricing dominance: never worse than the per-op-pass price on
/// any schedule (group- and schedule-level), and the flag off is the
/// legacy price to the bit.
#[test]
fn fused_pricing_dominates_and_off_is_legacy_bits() {
    forall(150, |rng| {
        let (g, view) = chain_graph(rng);
        let dev = if rng.chance(0.5) {
            DeviceProfile::kirin990()
        } else {
            DeviceProfile::qsd810()
        };
        let s = random_schedule(&g, &view, rng, true);
        let legacy = schedule_latency(&g, &s, &dev);
        let off = schedule_latency_fused(&g, &s, &dev, false);
        let on = schedule_latency_fused(&g, &s, &dev, true);
        ensure!(
            off.to_bits() == legacy.to_bits(),
            "fused=false diverged: {off} vs {legacy}"
        );
        ensure!(on <= legacy, "fused pricing worse: {on} vs {legacy}");
        for grp in &s.groups {
            let lg = group_latency(&g, grp, &dev);
            let fg = group_latency_fused(&g, grp, &dev, true);
            ensure!(fg <= lg, "group fused {fg} > per-op {lg}");
        }
        Ok(())
    });
}

/// A fused pricing context changes WHAT is priced, never the worker-count
/// determinism: `tune_parallel` under `fused = true` returns the same
/// bits for 1, 4, and 8 workers.
#[test]
fn fused_tuning_is_bit_identical_across_worker_counts() {
    let dev = DeviceProfile::kirin990();
    let (g, view) = {
        let mut rng = Rng::new(0xF05D);
        chain_graph(&mut rng)
    };
    let cfg = SearchConfig { budget: 200, seed: 0xA60, ..Default::default() };
    let mut results = Vec::new();
    for workers in [1usize, 4, 8] {
        let pool = ThreadPool::new(workers);
        let ctx = PricingContext::new_fused(&g, &dev, true);
        let mut cache = MemoCache::new();
        let r = tune_parallel(&g, &view, &cfg, None, &ctx, &mut cache, &pool);
        results.push(r);
    }
    for r in &results[1..] {
        assert_eq!(
            r.best_latency.to_bits(),
            results[0].best_latency.to_bits(),
            "best latency bits diverged across worker counts"
        );
        assert_eq!(r.best, results[0].best, "best schedule diverged");
        assert_eq!(r.evals, results[0].evals);
        assert_eq!(r.history, results[0].history);
    }
}

/// Compile-level worker independence + byte-exact round-trip: a fused
/// compile emits identical plan bytes for any `workers`, the bytes carry
/// the pattern tags, and `loaded_to_json` is a fixed point. An unfused
/// compile's bytes never mention patterns (the golden-compat contract).
#[test]
fn fused_compile_bytes_are_worker_independent_and_round_trip() {
    let g = build(ModelId::Sqn, InputShape::Small);
    let mut texts = Vec::new();
    for workers in [1usize, 4] {
        let cfg = CompileConfig {
            budget: 400,
            workers,
            fused: true,
            ..CompileConfig::new(DeviceProfile::kirin990())
        };
        let mut db = TuningDb::new();
        let out = compile_with_db(&g, &cfg, &mut db);
        texts.push((
            plan::to_json(&out, "SQN", "kirin990").pretty(),
            out.total_latency,
        ));
    }
    assert_eq!(texts[0].0, texts[1].0, "plan bytes depend on workers");
    assert_eq!(texts[0].1.to_bits(), texts[1].1.to_bits());
    assert!(texts[0].0.contains("\"patterns\""));
    let lp = plan::from_json(&Json::parse(&texts[0].0).unwrap()).unwrap();
    assert!(lp.patterns.is_some());
    let once = plan::loaded_to_json(&lp).pretty();
    let lp2 = plan::from_json(&Json::parse(&once).unwrap()).unwrap();
    assert_eq!(once, plan::loaded_to_json(&lp2).pretty());
    // unfused compile: no pattern field anywhere in the bytes
    let cfg = CompileConfig {
        budget: 400,
        ..CompileConfig::new(DeviceProfile::kirin990())
    };
    let mut db = TuningDb::new();
    let out = compile_with_db(&g, &cfg, &mut db);
    let plain = plan::to_json(&out, "SQN", "kirin990").pretty();
    assert!(!plain.contains("patterns"));
}

/// The probe-seeding contract: a tune warm-started from a schedule never
/// returns anything priced worse than that seed (the population keeps
/// its best member, and the seed is evaluated first).
#[test]
fn warm_seeded_tune_is_never_worse_than_its_seed() {
    forall(40, |rng| {
        let (g, view) = chain_graph(rng);
        let dev = DeviceProfile::qsd810();
        let fused = rng.chance(0.5);
        let seed_sched = random_schedule(&g, &view, rng, true);
        let seed_price = schedule_latency_fused(&g, &seed_sched, &dev, fused);
        let cfg = SearchConfig {
            budget: rng.range(30, 120),
            seed: rng.next_u64(),
            ..Default::default()
        };
        let pool = ThreadPool::new(3);
        let ctx = PricingContext::new_fused(&g, &dev, fused);
        let mut cache = MemoCache::new();
        let r = tune_parallel(
            &g,
            &view,
            &cfg,
            Some(seed_sched),
            &ctx,
            &mut cache,
            &pool,
        );
        ensure!(
            r.best_latency <= seed_price,
            "seeded tune regressed: {} vs seed {}",
            r.best_latency,
            seed_price
        );
        Ok(())
    });
}

//! Fleet farm determinism properties (PR 8):
//!
//! - `fleet_compile` over one zoo produces byte-identical merged-db and
//!   plan bytes at ANY worker count — parallelism changes wall-clock
//!   only, like every other layer.
//! - The sharded store is layout-transparent: saving one db at K ∈
//!   {1, 4, 16} and re-merging yields the same bytes, and saving at a
//!   new K over an old layout reshards in place.
//! - Job order (shuffles, duplicates) never changes the outcome: the
//!   fleet canonicalizes its job list.
//! - Concurrent savers UNION: N real threads writing overlapping dbs
//!   into one store lose nothing, and the merged result equals the
//!   order-free fold of every entry written.
//! - A warm rerun over an unchanged zoo leaves the db bytes unchanged
//!   and hits every class.

use ago::coordinator::{
    fleet_compile, plan, CompileConfig, DbEntry, FleetJob, ShardStore,
    TuningDb,
};
use ago::device::DeviceProfile;
use ago::models::{InputShape, ModelId};
use ago::tuner::schedule::{FusionGroup, GroupKind, Layout, Schedule, Tile};

fn zoo() -> Vec<FleetJob> {
    vec![
        FleetJob {
            model: ModelId::Mbn,
            shape: InputShape::Small,
            device: DeviceProfile::kirin990(),
        },
        FleetJob {
            model: ModelId::Sqn,
            shape: InputShape::Small,
            device: DeviceProfile::kirin990(),
        },
        FleetJob {
            model: ModelId::Mbn,
            shape: InputShape::Small,
            device: DeviceProfile::qsd810(),
        },
    ]
}

fn base_cfg(workers: usize) -> CompileConfig {
    CompileConfig {
        budget: 240,
        workers,
        ..CompileConfig::new(DeviceProfile::kirin990())
    }
}

/// Run the fleet and serialize everything comparable: (merged db bytes,
/// per-job plan bytes in canonical job order).
fn run(jobs: &[FleetJob], workers: usize) -> (String, Vec<String>) {
    let mut db = TuningDb::new();
    let out = fleet_compile(jobs, &base_cfg(workers), &mut db);
    let plans = out
        .jobs
        .iter()
        .zip(&out.models)
        .map(|(j, m)| {
            plan::to_json(m, j.model.name(), j.device.name).pretty()
        })
        .collect();
    (db.to_json().pretty(), plans)
}

#[test]
fn fleet_bytes_independent_of_worker_count() {
    let (db1, plans1) = run(&zoo(), 1);
    let (db4, plans4) = run(&zoo(), 4);
    assert_eq!(db1, db4, "merged db bytes depend on worker count");
    assert_eq!(plans1, plans4, "plan bytes depend on worker count");
}

#[test]
fn fleet_bytes_independent_of_job_order_and_duplicates() {
    let jobs = zoo();
    let mut shuffled = vec![
        jobs[2].clone(),
        jobs[0].clone(),
        jobs[1].clone(),
        jobs[0].clone(), // duplicate: must collapse, not recompile
    ];
    let (db_a, plans_a) = run(&jobs, 2);
    let (db_b, plans_b) = run(&shuffled, 2);
    assert_eq!(db_a, db_b, "merged db bytes depend on job order");
    assert_eq!(plans_a, plans_b, "plan bytes depend on job order");
    // and the canonical job list itself ignores the input order
    shuffled.rotate_left(1);
    let (db_c, _) = run(&shuffled, 2);
    assert_eq!(db_a, db_c);
}

#[test]
fn warm_rerun_hits_everything_and_preserves_db_bytes() {
    // BT's builder ignores the input shape, so BT@small and BT@middle
    // are two distinct fleet jobs over IDENTICAL graphs: every class of
    // the second is a ledger hit on the first — a guaranteed cross-job
    // dedup case (and a real exercise of cross-graph isomorphism
    // verification, since the anchor lives in a different Graph).
    let mut jobs = zoo();
    jobs.push(FleetJob {
        model: ModelId::Bt,
        shape: InputShape::Small,
        device: DeviceProfile::kirin990(),
    });
    jobs.push(FleetJob {
        model: ModelId::Bt,
        shape: InputShape::Middle,
        device: DeviceProfile::kirin990(),
    });
    let mut db = TuningDb::new();
    let cold = fleet_compile(&jobs, &base_cfg(2), &mut db);
    assert!(cold.stats.ledger_tasks > 0, "cold run must tune something");
    assert!(
        cold.stats.fleet_hits > 0,
        "assemble phase must splice from the ledger"
    );
    assert_eq!(
        cold.stats.ambiguous, 0,
        "zoo unexpectedly has ambiguous fingerprints"
    );
    assert!(
        cold.stats.ledger_tasks < cold.stats.classes,
        "no cross-compile dedup: {} tasks for {} class instances",
        cold.stats.ledger_tasks,
        cold.stats.classes
    );
    // the two BT jobs must assemble to byte-identical plans
    let bt: Vec<usize> = cold
        .jobs
        .iter()
        .enumerate()
        .filter(|(_, j)| j.model == ModelId::Bt)
        .map(|(i, _)| i)
        .collect();
    assert_eq!(bt.len(), 2);
    assert_eq!(
        plan::to_json(&cold.models[bt[0]], "BT", "kirin990").pretty(),
        plan::to_json(&cold.models[bt[1]], "BT", "kirin990").pretty(),
        "identical graphs assembled to different plans"
    );
    let bytes_cold = db.to_json().pretty();
    let warm = fleet_compile(&jobs, &base_cfg(2), &mut db);
    assert_eq!(
        warm.stats.ledger_tasks, 0,
        "warm rerun must tune nothing new"
    );
    assert_eq!(
        warm.stats.prior_hits, cold.stats.ledger_tasks,
        "every key the cold run tuned must be a prior hit warm"
    );
    assert_eq!(
        warm.stats.hit_rate, 1.0,
        "warm rerun must hit every class: {:?}",
        warm.stats
    );
    assert_eq!(
        bytes_cold,
        db.to_json().pretty(),
        "warm rerun changed db bytes"
    );
    // plans are byte-stable across the rerun too
    for (a, b) in cold.models.iter().zip(&warm.models) {
        assert_eq!(
            plan::to_json(a, "m", "d").pretty(),
            plan::to_json(b, "m", "d").pretty()
        );
    }
}

#[test]
fn shard_layout_is_transparent() {
    let (db_bytes, _) = run(&zoo()[..1], 2);
    let db = TuningDb::from_json(
        &ago::util::Json::parse(&db_bytes).unwrap(),
    )
    .unwrap();
    let base = std::env::temp_dir().join("ago_fleet_props_layout");
    std::fs::remove_dir_all(&base).ok();
    for k in [1usize, 4, 16] {
        let store = ShardStore::new(base.join(format!("k{k}")), k);
        store.save(&db).unwrap();
        let (merged, faults) = store.load_merged();
        assert!(faults.is_empty(), "unexpected faults: {faults:?}");
        assert_eq!(
            merged.to_json().pretty(),
            db_bytes,
            "shard count {k} changed merged bytes"
        );
    }
    // resharding: save at K=8 over the K=4 layout folds and replaces it
    let dir = base.join("k4");
    let re = ShardStore::new(&dir, 8);
    re.save(&TuningDb::new()).unwrap();
    let (merged, faults) = re.load_merged();
    assert!(faults.is_empty(), "{faults:?}");
    assert_eq!(merged.to_json().pretty(), db_bytes);
    let leftovers: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.contains("-of-004"))
        .collect();
    assert!(leftovers.is_empty(), "old layout not consumed: {leftovers:?}");
    std::fs::remove_dir_all(&base).ok();
}

/// A valid synthetic entry: one group covering `0..n_ops`.
fn entry(device: &str, fp: u64, latency: f64, evals: usize) -> DbEntry {
    let n_ops = 1 + (fp % 3) as usize;
    let schedule = Schedule {
        groups: vec![FusionGroup {
            ops: (0..n_ops).collect(),
            kind: GroupKind::Simple,
            tile: Tile { th: 4, tw: 4, tc: 8 },
            vec: 4,
            unroll: 2,
            threads: 2,
            layout: Layout::Nhwc,
        }],
    };
    let features = ago::costmodel::ClassFeatures::backfill(&schedule, n_ops);
    DbEntry {
        device: device.to_string(),
        variant: "ago".to_string(),
        fingerprint: fp,
        n_ops,
        schedule,
        latency,
        evals,
        features,
    }
}

#[test]
fn concurrent_savers_union() {
    let dir = std::env::temp_dir().join("ago_fleet_props_concurrent");
    std::fs::remove_dir_all(&dir).ok();
    // 8 writers, overlapping keys (same fp from two writers with
    // different latencies exercises the min-resolution under racing)
    let writer_dbs: Vec<TuningDb> = (0..8u64)
        .map(|w| {
            let mut db = TuningDb::new();
            for i in 0..6u64 {
                // high bits spread fingerprints across the shard space;
                // writers 2k and 2k+1 write the SAME six keys with
                // different latencies, so racing savers must resolve by
                // the total order, not by who wrote last
                let fp = (((w / 2) * 6 + i) << 56) | i;
                db.record(entry(
                    if (w / 2) % 2 == 0 { "kirin990" } else { "qsd810" },
                    fp,
                    1e-3 + (w as f64) * 1e-4,
                    10 + w as usize,
                ));
            }
            db
        })
        .collect();
    let mut reference = TuningDb::new();
    for db in &writer_dbs {
        for e in db.entries() {
            reference.record(e.clone());
        }
    }
    let handles: Vec<_> = writer_dbs
        .into_iter()
        .map(|db| {
            let dir = dir.clone();
            std::thread::spawn(move || {
                ShardStore::new(&dir, 4).save(&db).unwrap();
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let (merged, faults) = ShardStore::new(&dir, 4).load_merged();
    assert!(faults.is_empty(), "{faults:?}");
    assert_eq!(
        merged.to_json().pretty(),
        reference.to_json().pretty(),
        "concurrent saves lost or reordered entries"
    );
    std::fs::remove_dir_all(&dir).ok();
}

//! Integration properties for the learned cost model (`--learned`,
//! PR 9): corpus-pure fits, byte-determinism at any worker count,
//! inertness without a corpus, pure ranked-candidate provenance, and
//! never-worse warm seeding at the plan level.
//!
//! The closed-form per-feature properties (insertion-order-free fits,
//! exact feature JSON round-trips, backfill determinism) live as unit
//! tests in `costmodel::learned`; these tests exercise the same
//! contracts through the full compile pipeline.

use ago::coordinator::{
    compile_with_db, learned_fit, plan, CompileConfig, TuningDb, Variant,
    PROBE_MARGIN,
};
use ago::device::DeviceProfile;
use ago::models::{build, InputShape, ModelId};
use ago::util::Json;

/// A training corpus: three Small-shape models on kirin990. Enough
/// classes to clear the model's minimum corpus size.
fn corpus(budget: usize, workers: usize) -> TuningDb {
    let mut db = TuningDb::new();
    let cfg = CompileConfig {
        budget,
        workers,
        ..CompileConfig::new(DeviceProfile::kirin990())
    };
    for m in [ModelId::Mbn, ModelId::Sqn, ModelId::Sfn] {
        let g = build(m, InputShape::Small);
        compile_with_db(&g, &cfg, &mut db);
    }
    db
}

#[test]
fn fit_is_a_pure_function_of_the_corpus() {
    // worker count changes nothing about the corpus, hence nothing
    // about the fit: same model fingerprint, coefficient for
    // coefficient
    let db1 = corpus(500, 1);
    let db4 = corpus(500, 4);
    assert_eq!(
        db1.to_json().pretty(),
        db4.to_json().pretty(),
        "corpus bytes depend on worker count"
    );
    let m1 = learned_fit(&db1, Variant::Ago).expect("corpus above minimum");
    let m4 = learned_fit(&db4, Variant::Ago).expect("corpus above minimum");
    assert_eq!(m1.fingerprint(), m4.fingerprint());
    // a JSON round trip of the db (BTreeMap reorder, text re-parse)
    // cannot move the fit either
    let text = db1.to_json().pretty();
    let back = TuningDb::from_json(&Json::parse(&text).unwrap()).unwrap();
    let mb = learned_fit(&back, Variant::Ago).expect("round trip kept corpus");
    assert_eq!(mb.fingerprint(), m1.fingerprint());
    // the ablation variants have no entries in this corpus: no fit,
    // and every learned consumer stays inert rather than borrowing
    // cross-variant schedules
    assert!(learned_fit(&db1, Variant::AgoNi).is_none());
}

#[test]
fn learned_plan_and_db_bytes_are_worker_independent() {
    let base = corpus(500, 2);
    assert!(learned_fit(&base, Variant::Ago).is_some());
    let mk = |workers: usize| {
        let cfg = CompileConfig {
            budget: 500,
            workers,
            learned: true,
            ..CompileConfig::new(DeviceProfile::kirin990())
        };
        let g = build(ModelId::Mbn, InputShape::Middle);
        let mut db = base.clone();
        let m = compile_with_db(&g, &cfg, &mut db);
        (
            plan::to_json(&m, "mbn", "kirin990").pretty(),
            db.to_json().pretty(),
        )
    };
    let (p1, d1) = mk(1);
    let (p4, d4) = mk(4);
    let (p8, d8) = mk(8);
    assert_eq!(p1, p4, "learned plan bytes depend on worker count (1 vs 4)");
    assert_eq!(p1, p8, "learned plan bytes depend on worker count (1 vs 8)");
    assert_eq!(d1, d4, "learned db bytes depend on worker count (1 vs 4)");
    assert_eq!(d1, d8, "learned db bytes depend on worker count (1 vs 8)");
}

#[test]
fn learned_without_corpus_is_byte_inert() {
    // --learned against an empty db must reproduce the unlearned
    // compile exactly: no corpus, no model, no behavioral change
    let g = build(ModelId::Sqn, InputShape::Small);
    let mk = |learned: bool| {
        let cfg = CompileConfig {
            budget: 500,
            workers: 2,
            learned,
            ..CompileConfig::new(DeviceProfile::kirin990())
        };
        let mut db = TuningDb::new();
        let m = compile_with_db(&g, &cfg, &mut db);
        assert_eq!(m.learned_seeds, 0);
        (
            plan::to_json(&m, "sqn", "kirin990").pretty(),
            db.to_json().pretty(),
        )
    };
    let (p0, d0) = mk(false);
    let (p1, d1) = mk(true);
    assert_eq!(p0, p1, "empty-db --learned changed plan bytes");
    assert_eq!(d0, d1, "empty-db --learned changed db bytes");
}

#[test]
fn ranked_candidates_and_provenance_are_pure() {
    let base = corpus(500, 2);
    assert!(learned_fit(&base, Variant::Ago).is_some());
    let mk = || {
        let cfg = CompileConfig {
            budget: 600,
            workers: 2,
            learned: true,
            partition_candidates: 4,
            ..CompileConfig::new(DeviceProfile::kirin990())
        };
        let g = build(ModelId::Mbn, InputShape::Small);
        let mut db = base.clone();
        compile_with_db(&g, &cfg, &mut db)
    };
    let a = mk();
    let se = a.partition_search.as_ref().expect("provenance for K>1");
    // the adaptive margin is reported, floored, and capped
    assert!(se.margin >= PROBE_MARGIN);
    assert!(se.margin <= 0.40 + 1e-12);
    // learned scores align with the surviving candidates
    let ls = se.learned_scores.as_ref().expect("model ranked this sweep");
    assert_eq!(ls.len(), se.probe_scores.len());
    assert_eq!(ls.len(), se.labels.len());
    assert!(ls.iter().all(|v| v.is_finite() && *v > 0.0));
    // plan JSON carries the new provenance fields
    let pj = plan::to_json(&a, "mbn", "kirin990").pretty();
    assert!(pj.contains("\"margin\""));
    assert!(pj.contains("\"pruned\""));
    assert!(pj.contains("\"learned_scores_s\""));
    // purity: the ranked sweep and everything downstream of it repeat
    // bit for bit
    let b = mk();
    let sb = b.partition_search.as_ref().unwrap();
    assert_eq!(se.labels, sb.labels);
    assert_eq!(se.probe_scores, sb.probe_scores);
    assert_eq!(se.learned_scores, sb.learned_scores);
    assert_eq!(se.margin, sb.margin);
    assert_eq!(se.pruned, sb.pruned);
    assert_eq!(a.schedules, b.schedules);

    // an UNLEARNED K>1 compile reports the margin but no learned
    // fields beyond `pruned: 0`
    let cfg = CompileConfig {
        budget: 600,
        workers: 2,
        partition_candidates: 4,
        ..CompileConfig::new(DeviceProfile::kirin990())
    };
    let g = build(ModelId::Mbn, InputShape::Small);
    let plain = compile_with_db(&g, &cfg, &mut TuningDb::new());
    let sp = plain.partition_search.as_ref().unwrap();
    assert_eq!(sp.pruned, 0);
    assert!(sp.learned_scores.is_none());
    let qj = plan::to_json(&plain, "mbn", "kirin990").pretty();
    assert!(qj.contains("\"margin\""));
    assert!(!qj.contains("learned_scores_s"));
}

#[test]
fn learned_compile_is_never_worse_at_the_plan_level() {
    // the transfer gate's whole point: whatever the NN seed does to
    // the search trajectory, the emitted plan must not regress beyond
    // the search's own 1% improvement resolution
    let base = corpus(500, 2);
    let mk = |learned: bool| {
        let cfg = CompileConfig {
            budget: 500,
            workers: 2,
            learned,
            ..CompileConfig::new(DeviceProfile::kirin990())
        };
        let g = build(ModelId::Mbn, InputShape::Middle);
        let mut db = base.clone();
        compile_with_db(&g, &cfg, &mut db)
    };
    let cold = mk(false);
    let warm = mk(true);
    assert_eq!(cold.learned_seeds, 0);
    assert!(
        warm.total_latency <= cold.total_latency * 1.01,
        "learned {} worse than baseline {}",
        warm.total_latency,
        cold.total_latency
    );
    // whatever the gate decided, the accounting is consistent: seeds
    // never exceed the class count
    assert!(warm.learned_seeds <= warm.n_classes);
}

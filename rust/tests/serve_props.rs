//! Property tests for the serving scheduler (`serve::scheduler`) over
//! randomized workloads, batch bounds, queue depths, and worker counts:
//!
//! - per-model FIFO fairness: a model's responses complete in its
//!   arrival order
//! - no batch ever exceeds the configured bound
//! - no request is dropped or double-executed
//! - with `SimExecutor`, responses AND serialized stats are bit-identical
//!   between a 1-thread and an N-thread run of the same seed
//!
//! Plans are handcrafted (no compile), so these run on any checkout in
//! milliseconds per case.

use std::collections::BTreeMap;
use std::sync::Arc;

use ago::coordinator::plan::LoadedPlan;
use ago::ensure;
use ago::graph::Partition;
use ago::serve::{
    mixed_workload, serve, PlanRegistry, Request, ServeConfig, SimExecutor,
};
use ago::tuner::schedule::{FusionGroup, GroupKind, Layout, Schedule, Tile};
use ago::util::propkit::forall;
use ago::util::Rng;

/// Handcrafted plan: `lats_us.len()` subgraphs of two ops each. A copy
/// of `serve::testutil::toy_plan` — integration tests cannot reach the
/// library's `#[cfg(test)]` items, so keep the two in sync.
fn toy_plan(model: &str, device: &str, lats_us: &[f64]) -> LoadedPlan {
    let n = lats_us.len();
    LoadedPlan {
        model: model.to_string(),
        device: device.to_string(),
        partition: Partition::from_assignment(
            (0..n).flat_map(|g| [g, g]).collect(),
        ),
        schedules: (0..n)
            .map(|g| Schedule {
                groups: vec![FusionGroup {
                    ops: vec![2 * g, 2 * g + 1],
                    kind: GroupKind::Epilogue,
                    tile: Tile { th: 4, tw: 4, tc: 8 },
                    vec: 8,
                    unroll: 4,
                    threads: 2,
                    layout: Layout::Nhwc,
                }],
            })
            .collect(),
        subgraph_latency: lats_us.iter().map(|l| l * 1e-6).collect(),
        total_latency_ms: 0.0,
        partition_search: None,
        patterns: None,
    }
}

/// Random registry of 1–3 models with random subgraph counts/latencies.
fn random_registry(rng: &mut Rng) -> PlanRegistry {
    let names = ["ALPHA", "BETA", "GAMMA"];
    let n_models = rng.range(1, 4);
    let mut reg = PlanRegistry::new();
    for name in names.iter().take(n_models) {
        let n_sub = rng.range(1, 7);
        let lats: Vec<f64> =
            (0..n_sub).map(|_| 5.0 + rng.f64() * 200.0).collect();
        let device = if rng.chance(0.5) { "kirin990" } else { "qsd810" };
        reg.register(toy_plan(name, device, &lats)).unwrap();
    }
    reg
}

#[test]
fn no_drop_no_dup_fifo_and_batch_bound() {
    forall(40, |rng| {
        let reg = random_registry(rng);
        let n = rng.range(1, 250);
        let wl = mixed_workload(&reg.models(), n, rng.next_u64());
        let cfg = ServeConfig {
            max_batch: rng.range(1, 10),
            queue_depth: rng.range(1, 20),
            workers: rng.range(1, 5),
        };
        let out = serve(&reg, &cfg, Arc::new(SimExecutor), wl.clone())
            .map_err(|e| format!("{e:#}"))?;
        // exactly-once: the response ids are a permutation of the inputs
        ensure!(
            out.responses.len() == n,
            "{} responses for {n} requests",
            out.responses.len()
        );
        let mut ids: Vec<u64> =
            out.responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ensure!(
            ids == (0..n as u64).collect::<Vec<_>>(),
            "dropped or duplicated ids"
        );
        ensure!(out.stats.dropped == 0, "dropped {}", out.stats.dropped);
        ensure!(out.stats.completed == n, "completed {}", out.stats.completed);
        // batch bound
        ensure!(
            out.responses.iter().all(|r| {
                r.batch_size >= 1 && r.batch_size <= cfg.max_batch
            }),
            "batch bound {} violated",
            cfg.max_batch
        );
        // per-model FIFO fairness: completion order restricted to one
        // model equals that model's arrival order
        let mut arrival: BTreeMap<&str, Vec<u64>> = BTreeMap::new();
        for r in &wl {
            arrival.entry(r.model.as_str()).or_default().push(r.id);
        }
        let mut completion: BTreeMap<&str, Vec<u64>> = BTreeMap::new();
        for r in &out.responses {
            completion.entry(r.model.as_str()).or_default().push(r.id);
        }
        ensure!(
            arrival == completion,
            "per-model FIFO violated: {arrival:?} vs {completion:?}"
        );
        Ok(())
    });
}

#[test]
fn sim_results_bit_identical_across_worker_counts() {
    forall(25, |rng| {
        let reg = random_registry(rng);
        let n = rng.range(1, 200);
        let seed = rng.next_u64();
        let wl = mixed_workload(&reg.models(), n, seed);
        let base = ServeConfig {
            max_batch: rng.range(1, 10),
            queue_depth: rng.range(1, 24),
            workers: 1,
        };
        let one = serve(&reg, &base, Arc::new(SimExecutor), wl.clone())
            .map_err(|e| format!("{e:#}"))?;
        for workers in [2, rng.range(3, 8)] {
            let cfg = ServeConfig { workers, ..base.clone() };
            let many = serve(&reg, &cfg, Arc::new(SimExecutor), wl.clone())
                .map_err(|e| format!("{e:#}"))?;
            // responses: same order, same ids, same batch sizes, same
            // latency BITS, same checksums
            ensure!(
                one.responses.len() == many.responses.len(),
                "response count differs"
            );
            for (a, b) in one.responses.iter().zip(&many.responses) {
                ensure!(
                    a.id == b.id
                        && a.model == b.model
                        && a.batch_size == b.batch_size
                        && a.latency_s.to_bits() == b.latency_s.to_bits()
                        && a.checksum == b.checksum,
                    "response diverged across worker counts: \
                     {a:?} vs {b:?} ({workers} workers)"
                );
            }
            // serialized stats: byte-identical
            ensure!(
                one.stats.to_json().pretty()
                    == many.stats.to_json().pretty(),
                "stats diverged at {workers} workers"
            );
        }
        Ok(())
    });
}

#[test]
fn serve_twice_is_bit_identical() {
    // same seed, same config -> same everything (run-to-run determinism,
    // the property the CI smoke diffs via --stats-out)
    forall(15, |rng| {
        let reg = random_registry(rng);
        let wl =
            mixed_workload(&reg.models(), rng.range(1, 150), rng.next_u64());
        let cfg = ServeConfig {
            max_batch: rng.range(1, 9),
            queue_depth: rng.range(1, 16),
            workers: 0, // host-sized pool: still deterministic
        };
        let a = serve(&reg, &cfg, Arc::new(SimExecutor), wl.clone())
            .map_err(|e| format!("{e:#}"))?;
        let b = serve(&reg, &cfg, Arc::new(SimExecutor), wl)
            .map_err(|e| format!("{e:#}"))?;
        ensure!(a.responses == b.responses, "responses differ run-to-run");
        ensure!(
            a.stats.to_json().pretty() == b.stats.to_json().pretty(),
            "stats differ run-to-run"
        );
        Ok(())
    });
}

#[test]
fn acceptance_1k_mixed_two_model_workload() {
    // the PR acceptance scenario at test scale: 1000 requests over two
    // models through SimExecutor — zero drops, deterministic, batched
    // throughput at least 2x the batch-1 configuration
    let mut reg = PlanRegistry::new();
    reg.register(toy_plan("MBN", "kirin990", &[30.0, 90.0, 45.0, 120.0]))
        .unwrap();
    reg.register(toy_plan("SQN", "qsd810", &[60.0, 20.0, 80.0])).unwrap();
    let wl = mixed_workload(&reg.models(), 1000, 42);
    let run = |max_batch: usize| {
        serve(
            &reg,
            &ServeConfig { max_batch, queue_depth: 64, workers: 0 },
            Arc::new(SimExecutor),
            wl.clone(),
        )
        .unwrap()
    };
    let batched = run(16);
    assert_eq!(batched.stats.completed, 1000);
    assert_eq!(batched.stats.dropped, 0);
    let again = run(16);
    assert_eq!(
        batched.stats.to_json().pretty(),
        again.stats.to_json().pretty(),
        "1k workload stats must be bit-identical across runs"
    );
    let unbatched = run(1);
    assert!(
        batched.stats.throughput_rps()
            >= 2.0 * unbatched.stats.throughput_rps(),
        "batched {:.0} rps < 2x unbatched {:.0} rps",
        batched.stats.throughput_rps(),
        unbatched.stats.throughput_rps()
    );
}

#[test]
fn single_request_roundtrip() {
    let mut reg = PlanRegistry::new();
    reg.register(toy_plan("SOLO", "kirin990", &[100.0])).unwrap();
    let wl = vec![Request { id: 0, model: "SOLO".to_string(), seed: 9 }];
    let out = serve(
        &reg,
        &ServeConfig::default(),
        Arc::new(SimExecutor),
        wl,
    )
    .unwrap();
    assert_eq!(out.responses.len(), 1);
    assert_eq!(out.responses[0].batch_size, 1);
    assert!(out.responses[0].latency_s > 0.0);
    assert_eq!(out.stats.batches, 1);
}

//! Property tests for the serving scheduler (`serve::scheduler`) over
//! randomized workloads, batch bounds, queue depths, and worker counts:
//!
//! - per-model FIFO fairness: a model's responses complete in its
//!   arrival order (closed-loop mode)
//! - no batch ever exceeds the configured bound
//! - no request is dropped or double-executed; in timed mode, completed
//!   and shed requests partition the workload under every policy
//! - with `SimExecutor`, responses AND serialized stats are bit-identical
//!   between a 1-thread and an N-thread run of the same seed — in both
//!   scheduling modes, with and without hot-swap
//! - hot-swap atomicity: an executor only ever observes whole plans,
//!   with at most one switch point per model, and a margin-rejected
//!   swap leaves the run bit-identical to hot-swap disabled
//! - the serialized stats key sets are pinned: legacy serializations
//!   carry exactly the pre-clock keys, timed ones add exactly `timed`
//!   and the per-model `shed`
//! - the scheduling win itself: EDF beats round-robin on strict-tier
//!   tail latency for an overloaded bursty trace
//!
//! Plans are handcrafted (no compile), so these run on any checkout in
//! milliseconds per case.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use ago::coordinator::plan::LoadedPlan;
use ago::ensure;
use ago::graph::Partition;
use ago::serve::{
    bursty_workload, mixed_workload, serve, Executor, HotSwapConfig,
    PlanRegistry, Policy, Request, Response, ServeConfig, ServingPlan,
    SimExecutor, TimedConfig, TrafficConfig,
};
use ago::tuner::schedule::{FusionGroup, GroupKind, Layout, Schedule, Tile};
use ago::util::json::Json;
use ago::util::propkit::forall;
use ago::util::Rng;

/// Handcrafted plan: `lats_us.len()` subgraphs of two ops each. A copy
/// of `serve::testutil::toy_plan` — integration tests cannot reach the
/// library's `#[cfg(test)]` items, so keep the two in sync.
fn toy_plan(model: &str, device: &str, lats_us: &[f64]) -> LoadedPlan {
    let n = lats_us.len();
    LoadedPlan {
        model: model.to_string(),
        device: device.to_string(),
        partition: Partition::from_assignment(
            (0..n).flat_map(|g| [g, g]).collect(),
        ),
        schedules: (0..n)
            .map(|g| Schedule {
                groups: vec![FusionGroup {
                    ops: vec![2 * g, 2 * g + 1],
                    kind: GroupKind::Epilogue,
                    tile: Tile { th: 4, tw: 4, tc: 8 },
                    vec: 8,
                    unroll: 4,
                    threads: 2,
                    layout: Layout::Nhwc,
                }],
            })
            .collect(),
        subgraph_latency: lats_us.iter().map(|l| l * 1e-6).collect(),
        total_latency_ms: 0.0,
        partition_search: None,
        patterns: None,
        backends: None,
    }
}

/// Random registry of 1–3 models with random subgraph counts/latencies.
fn random_registry(rng: &mut Rng) -> PlanRegistry {
    let names = ["ALPHA", "BETA", "GAMMA"];
    let n_models = rng.range(1, 4);
    let mut reg = PlanRegistry::new();
    for name in names.iter().take(n_models) {
        let n_sub = rng.range(1, 7);
        let lats: Vec<f64> =
            (0..n_sub).map(|_| 5.0 + rng.f64() * 200.0).collect();
        let device = if rng.chance(0.5) { "kirin990" } else { "qsd810" };
        reg.register(toy_plan(name, device, &lats)).unwrap();
    }
    reg
}

/// Mean batch-1 capacity of a registry, requests per second — the knee
/// rate the timed-mode tests calibrate their traffic against.
fn knee_rps(reg: &PlanRegistry) -> f64 {
    let b1: Vec<f64> = reg
        .models()
        .iter()
        .map(|m| reg.get(m).unwrap().sim.batch_seconds(1))
        .collect();
    b1.len() as f64 / b1.iter().sum::<f64>()
}

fn timed_cfg(policy: Policy) -> ServeConfig {
    ServeConfig {
        max_batch: 8,
        queue_depth: 64,
        workers: 1,
        timed: Some(TimedConfig { policy, hot_swap: None }),
    }
}

/// The bench-scale two-model registry used by the fixed-scenario tests.
fn bench_registry() -> PlanRegistry {
    let mut reg = PlanRegistry::new();
    reg.register(toy_plan("MBN", "kirin990", &[300.0, 900.0, 450.0, 1200.0]))
        .unwrap();
    reg.register(toy_plan("SQN", "qsd810", &[600.0, 200.0, 800.0])).unwrap();
    reg
}

#[test]
fn no_drop_no_dup_fifo_and_batch_bound() {
    forall(40, |rng| {
        let reg = random_registry(rng);
        let n = rng.range(1, 250);
        let wl = mixed_workload(&reg.models(), n, rng.next_u64());
        let cfg = ServeConfig {
            max_batch: rng.range(1, 10),
            queue_depth: rng.range(1, 20),
            workers: rng.range(1, 5),
            timed: None,
        };
        let out = serve(&reg, &cfg, Arc::new(SimExecutor), wl.clone())
            .map_err(|e| format!("{e:#}"))?;
        // exactly-once: the response ids are a permutation of the inputs
        ensure!(
            out.responses.len() == n,
            "{} responses for {n} requests",
            out.responses.len()
        );
        let mut ids: Vec<u64> =
            out.responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ensure!(
            ids == (0..n as u64).collect::<Vec<_>>(),
            "dropped or duplicated ids"
        );
        ensure!(out.stats.dropped == 0, "dropped {}", out.stats.dropped);
        ensure!(out.stats.completed == n, "completed {}", out.stats.completed);
        // batch bound
        ensure!(
            out.responses.iter().all(|r| {
                r.batch_size >= 1 && r.batch_size <= cfg.max_batch
            }),
            "batch bound {} violated",
            cfg.max_batch
        );
        // per-model FIFO fairness: completion order restricted to one
        // model equals that model's arrival order
        let mut arrival: BTreeMap<&str, Vec<u64>> = BTreeMap::new();
        for r in &wl {
            arrival.entry(r.model.as_str()).or_default().push(r.id);
        }
        let mut completion: BTreeMap<&str, Vec<u64>> = BTreeMap::new();
        for r in &out.responses {
            completion.entry(r.model.as_str()).or_default().push(r.id);
        }
        ensure!(
            arrival == completion,
            "per-model FIFO violated: {arrival:?} vs {completion:?}"
        );
        Ok(())
    });
}

#[test]
fn sim_results_bit_identical_across_worker_counts() {
    forall(25, |rng| {
        let reg = random_registry(rng);
        let n = rng.range(1, 200);
        let seed = rng.next_u64();
        let wl = mixed_workload(&reg.models(), n, seed);
        let base = ServeConfig {
            max_batch: rng.range(1, 10),
            queue_depth: rng.range(1, 24),
            workers: 1,
            timed: None,
        };
        let one = serve(&reg, &base, Arc::new(SimExecutor), wl.clone())
            .map_err(|e| format!("{e:#}"))?;
        for workers in [2, rng.range(3, 8)] {
            let cfg = ServeConfig { workers, ..base.clone() };
            let many = serve(&reg, &cfg, Arc::new(SimExecutor), wl.clone())
                .map_err(|e| format!("{e:#}"))?;
            // responses: same order, same ids, same batch sizes, same
            // latency BITS, same checksums
            ensure!(
                one.responses.len() == many.responses.len(),
                "response count differs"
            );
            for (a, b) in one.responses.iter().zip(&many.responses) {
                ensure!(
                    a.id == b.id
                        && a.model == b.model
                        && a.batch_size == b.batch_size
                        && a.latency_s.to_bits() == b.latency_s.to_bits()
                        && a.checksum == b.checksum,
                    "response diverged across worker counts: \
                     {a:?} vs {b:?} ({workers} workers)"
                );
            }
            // serialized stats: byte-identical
            ensure!(
                one.stats.to_json().pretty()
                    == many.stats.to_json().pretty(),
                "stats diverged at {workers} workers"
            );
        }
        Ok(())
    });
}

#[test]
fn serve_twice_is_bit_identical() {
    // same seed, same config -> same everything (run-to-run determinism,
    // the property the CI smoke diffs via --stats-out)
    forall(15, |rng| {
        let reg = random_registry(rng);
        let wl =
            mixed_workload(&reg.models(), rng.range(1, 150), rng.next_u64());
        let cfg = ServeConfig {
            max_batch: rng.range(1, 9),
            queue_depth: rng.range(1, 16),
            workers: 0, // host-sized pool: still deterministic
            timed: None,
        };
        let a = serve(&reg, &cfg, Arc::new(SimExecutor), wl.clone())
            .map_err(|e| format!("{e:#}"))?;
        let b = serve(&reg, &cfg, Arc::new(SimExecutor), wl)
            .map_err(|e| format!("{e:#}"))?;
        ensure!(a.responses == b.responses, "responses differ run-to-run");
        ensure!(
            a.stats.to_json().pretty() == b.stats.to_json().pretty(),
            "stats differ run-to-run"
        );
        Ok(())
    });
}

#[test]
fn acceptance_1k_mixed_two_model_workload() {
    // the PR acceptance scenario at test scale: 1000 requests over two
    // models through SimExecutor — zero drops, deterministic, batched
    // throughput at least 2x the batch-1 configuration
    let mut reg = PlanRegistry::new();
    reg.register(toy_plan("MBN", "kirin990", &[30.0, 90.0, 45.0, 120.0]))
        .unwrap();
    reg.register(toy_plan("SQN", "qsd810", &[60.0, 20.0, 80.0])).unwrap();
    let wl = mixed_workload(&reg.models(), 1000, 42);
    let run = |max_batch: usize| {
        serve(
            &reg,
            &ServeConfig {
                max_batch,
                queue_depth: 64,
                workers: 0,
                timed: None,
            },
            Arc::new(SimExecutor),
            wl.clone(),
        )
        .unwrap()
    };
    let batched = run(16);
    assert_eq!(batched.stats.completed, 1000);
    assert_eq!(batched.stats.dropped, 0);
    let again = run(16);
    assert_eq!(
        batched.stats.to_json().pretty(),
        again.stats.to_json().pretty(),
        "1k workload stats must be bit-identical across runs"
    );
    let unbatched = run(1);
    assert!(
        batched.stats.throughput_rps()
            >= 2.0 * unbatched.stats.throughput_rps(),
        "batched {:.0} rps < 2x unbatched {:.0} rps",
        batched.stats.throughput_rps(),
        unbatched.stats.throughput_rps()
    );
}

#[test]
fn single_request_roundtrip() {
    let mut reg = PlanRegistry::new();
    reg.register(toy_plan("SOLO", "kirin990", &[100.0])).unwrap();
    let wl = vec![Request::closed(0, "SOLO", 9)];
    let out = serve(
        &reg,
        &ServeConfig::default(),
        Arc::new(SimExecutor),
        wl,
    )
    .unwrap();
    assert_eq!(out.responses.len(), 1);
    assert_eq!(out.responses[0].batch_size, 1);
    assert!(out.responses[0].latency_s > 0.0);
    assert_eq!(out.stats.batches, 1);
}

// ---- timed (simulated clock) mode -----------------------------------

#[test]
fn timed_accounting_holds_under_every_policy() {
    // completed + shed partition the workload for any policy, any trace
    // intensity — nothing vanishes, nothing is answered twice
    forall(12, |rng| {
        let reg = random_registry(rng);
        let knee = knee_rps(&reg);
        let n = rng.range(100, 600);
        let tcfg = TrafficConfig {
            rate_rps: (0.5 + 2.5 * rng.f64()) * knee,
            slo_s: (4.0 + 12.0 * rng.f64()) / knee,
            burst_prob: 0.04,
            ..Default::default()
        };
        let wl = bursty_workload(&reg.models(), n, rng.next_u64(), &tcfg);
        let max_batch = rng.range(1, 12);
        let queue_depth = rng.range(4, 48);
        for policy in [Policy::RoundRobin, Policy::Edf, Policy::EdfShed] {
            let cfg = ServeConfig {
                max_batch,
                queue_depth,
                workers: 1,
                timed: Some(TimedConfig { policy, hot_swap: None }),
            };
            let out = serve(&reg, &cfg, Arc::new(SimExecutor), wl.clone())
                .map_err(|e| format!("{e:#}"))?;
            let t = out.stats.timed.as_ref().expect("timed stats");
            ensure!(
                out.stats.completed + out.shed.len() == n,
                "{policy:?}: {} completed + {} shed != {n}",
                out.stats.completed,
                out.shed.len()
            );
            ensure!(
                out.stats.dropped == out.shed.len()
                    && t.shed == out.shed.len(),
                "{policy:?}: shed accounting disagrees"
            );
            if policy != Policy::EdfShed {
                ensure!(
                    out.shed.is_empty(),
                    "{policy:?} must never shed, shed {}",
                    out.shed.len()
                );
            }
            // the union of response ids and shed ids is the workload
            let mut ids: Vec<u64> = out
                .responses
                .iter()
                .map(|r| r.id)
                .chain(out.shed.iter().map(|r| r.id))
                .collect();
            ids.sort_unstable();
            ensure!(
                ids == (0..n as u64).collect::<Vec<_>>(),
                "{policy:?}: completed+shed is not a partition"
            );
            // per-model rollups agree with the totals
            let c: usize =
                out.stats.per_model.values().map(|m| m.completed).sum();
            let s: usize =
                out.stats.per_model.values().map(|m| m.shed).sum();
            ensure!(c == out.stats.completed, "{policy:?}: completed rollup");
            ensure!(s == t.shed, "{policy:?}: shed rollup");
            ensure!(
                out.responses
                    .iter()
                    .all(|r| r.batch_size >= 1 && r.batch_size <= max_batch),
                "{policy:?}: batch bound violated"
            );
        }
        Ok(())
    });
}

#[test]
fn timed_results_bit_identical_across_worker_counts() {
    // the extended determinism contract: on the simulated clock the
    // worker pool only hosts background recompiles, so responses and
    // stats must be bit-identical at any worker count — for every
    // policy, and with hot-swap enabled (the join is clock-anchored)
    let reg = bench_registry();
    let knee = knee_rps(&reg);
    let tcfg = TrafficConfig {
        rate_rps: 1.5 * knee,
        slo_s: 20.0 / knee,
        ..Default::default()
    };
    let wl = bursty_workload(&reg.models(), 800, 42, &tcfg);
    for policy in [Policy::RoundRobin, Policy::Edf, Policy::EdfShed] {
        let run = |workers: usize| {
            let cfg = ServeConfig { workers, ..timed_cfg(policy) };
            serve(&reg, &cfg, Arc::new(SimExecutor), wl.clone()).unwrap()
        };
        let one = run(1);
        for workers in [4, 8] {
            let many = run(workers);
            for (a, b) in one.responses.iter().zip(&many.responses) {
                assert!(
                    a.id == b.id
                        && a.latency_s.to_bits() == b.latency_s.to_bits()
                        && a.checksum == b.checksum,
                    "{policy:?}: response diverged at {workers} workers: \
                     {a:?} vs {b:?}"
                );
            }
            assert_eq!(one.shed, many.shed, "{policy:?} at {workers}");
            assert_eq!(
                one.stats.to_json().pretty(),
                many.stats.to_json().pretty(),
                "{policy:?}: stats diverged at {workers} workers"
            );
        }
    }
    // hot-swap enabled: the recompile runs on the pool, but the join is
    // anchored to the simulated clock — still worker-count independent
    let faster = |m: &str| -> Option<LoadedPlan> {
        match m {
            "MBN" => Some(toy_plan(
                "MBN",
                "kirin990",
                &[210.0, 630.0, 315.0, 840.0],
            )),
            "SQN" => Some(toy_plan("SQN", "qsd810", &[420.0, 140.0, 560.0])),
            _ => None,
        }
    };
    let run_hs = |workers: usize| {
        let reg = bench_registry(); // fresh: an accepted swap mutates it
        let mut cfg = ServeConfig { workers, ..timed_cfg(Policy::Edf) };
        cfg.timed.as_mut().unwrap().hot_swap =
            Some(HotSwapConfig::new(Arc::new(faster)));
        serve(&reg, &cfg, Arc::new(SimExecutor), wl.clone()).unwrap()
    };
    let one = run_hs(1);
    assert!(
        one.stats
            .timed
            .as_ref()
            .unwrap()
            .swaps
            .iter()
            .any(|sw| sw.accepted),
        "the 30%-faster candidates must clear the margin"
    );
    for workers in [4, 8] {
        let many = run_hs(workers);
        assert_eq!(one.responses, many.responses, "hot-swap at {workers}");
        assert_eq!(
            one.stats.to_json().pretty(),
            many.stats.to_json().pretty(),
            "hot-swap stats diverged at {workers} workers"
        );
    }
}

/// Wraps the simulated backend and records a whole-plan signature per
/// executed batch — the probe for the "no torn plan" property.
#[derive(Default)]
struct RecordingExecutor {
    /// (model, signature of every subgraph latency bit) per batch, in
    /// execution order.
    seen: Mutex<Vec<(String, u64)>>,
}

impl Executor for RecordingExecutor {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn execute_batch(
        &self,
        plan: &ServingPlan,
        batch: &[Request],
    ) -> Result<Vec<Response>> {
        let sig = plan
            .plan
            .subgraph_latency
            .iter()
            .fold(0xcbf29ce484222325u64, |acc, l| {
                (acc ^ l.to_bits()).wrapping_mul(0x100000001b3)
            });
        self.seen.lock().unwrap().push((plan.model.clone(), sig));
        SimExecutor.execute_batch(plan, batch)
    }
}

/// Collapse each model's per-batch signature stream into its run-length
/// shape: a torn or flapping plan shows up as more than one transition.
fn signature_runs(seen: &[(String, u64)]) -> BTreeMap<String, Vec<u64>> {
    let mut runs: BTreeMap<String, Vec<u64>> = BTreeMap::new();
    for (m, sig) in seen {
        let r = runs.entry(m.clone()).or_default();
        if r.last() != Some(sig) {
            r.push(*sig);
        }
    }
    runs
}

#[test]
fn hot_swap_never_serves_a_torn_plan() {
    let knee = knee_rps(&bench_registry());
    let tcfg = TrafficConfig {
        rate_rps: 1.5 * knee,
        slo_s: 20.0 / knee,
        ..Default::default()
    };
    let wl = bursty_workload(
        &bench_registry().models(),
        600,
        77,
        &tcfg,
    );

    // accepted swaps: every batch sees exactly the old plan or exactly
    // the new one, with a single switch point per model
    let faster = |m: &str| -> Option<LoadedPlan> {
        match m {
            "MBN" => Some(toy_plan(
                "MBN",
                "kirin990",
                &[210.0, 630.0, 315.0, 840.0],
            )),
            "SQN" => Some(toy_plan("SQN", "qsd810", &[420.0, 140.0, 560.0])),
            _ => None,
        }
    };
    let reg = bench_registry();
    let mut cfg = timed_cfg(Policy::Edf);
    cfg.timed.as_mut().unwrap().hot_swap =
        Some(HotSwapConfig::new(Arc::new(faster)));
    let rec = Arc::new(RecordingExecutor::default());
    let on = serve(&reg, &cfg, rec.clone(), wl.clone()).unwrap();
    assert!(on
        .stats
        .timed
        .as_ref()
        .unwrap()
        .swaps
        .iter()
        .all(|sw| sw.accepted));
    let runs = signature_runs(&rec.seen.lock().unwrap());
    for (m, r) in &runs {
        assert_eq!(
            r.len(),
            2,
            "{m}: expected exactly one plan switch, saw runs {r:?}"
        );
    }

    // margin-rejected swaps: the executor sees one plan per model for
    // the whole run, and the run is bit-identical to hot-swap disabled
    let base = serve(
        &bench_registry(),
        &timed_cfg(Policy::Edf),
        Arc::new(SimExecutor),
        wl.clone(),
    )
    .unwrap();
    let slight = |m: &str| -> Option<LoadedPlan> {
        match m {
            "MBN" => Some(toy_plan(
                "MBN",
                "kirin990",
                &[270.0, 810.0, 405.0, 1080.0],
            )),
            "SQN" => Some(toy_plan("SQN", "qsd810", &[540.0, 180.0, 720.0])),
            _ => None,
        }
    };
    let mut cfg = timed_cfg(Policy::Edf);
    cfg.timed.as_mut().unwrap().hot_swap =
        Some(HotSwapConfig::new(Arc::new(slight)));
    let rec = Arc::new(RecordingExecutor::default());
    let rej = serve(&bench_registry(), &cfg, rec.clone(), wl).unwrap();
    assert!(rej
        .stats
        .timed
        .as_ref()
        .unwrap()
        .swaps
        .iter()
        .all(|sw| !sw.accepted));
    let runs = signature_runs(&rec.seen.lock().unwrap());
    for (m, r) in &runs {
        assert_eq!(r.len(), 1, "{m}: rejected swap must not change the plan");
    }
    assert_eq!(rej.responses, base.responses);
    assert_eq!(rej.stats.workload_digest, base.stats.workload_digest);
    assert_eq!(
        rej.stats.serial_s.to_bits(),
        base.stats.serial_s.to_bits()
    );
}

/// Keys of a serialized object, in emission (sorted) order.
fn keys(j: &Json) -> Vec<String> {
    match j {
        Json::Obj(m) => m.keys().cloned().collect(),
        other => panic!("expected an object, got {other:?}"),
    }
}

#[test]
fn stats_key_sets_are_pinned() {
    const LEGACY_TOP: &[&str] = &[
        "backpressure_stalls",
        "batches",
        "completed",
        "dropped",
        "executor",
        "max_batch",
        "models",
        "queue_depth",
        "requests",
        "serial_ms",
        "throughput_rps",
        "workload_digest",
    ];
    const LEGACY_MODEL: &[&str] = &[
        "batches",
        "busy_ms",
        "completed",
        "lat_max_ms",
        "lat_mean_ms",
        "lat_min_ms",
        "lat_p50_ms",
        "lat_p99_ms",
        "max_batch",
        "mean_batch",
        "throughput_rps",
    ];
    const TIMED_BLOCK: &[&str] = &[
        "deadline_misses",
        "lat_p50_ms",
        "lat_p99_ms",
        "policy",
        "shed",
        "sim_end_ms",
        "swaps",
        "tier0_completed",
        "tier0_misses",
        "tier0_p99_ms",
    ];
    let reg = bench_registry();

    // legacy mode: exactly the pre-clock serialization surface, so stats
    // files written before the simulated clock existed stay byte-stable
    let wl = mixed_workload(&reg.models(), 200, 5);
    let legacy = serve(
        &reg,
        &ServeConfig {
            max_batch: 8,
            queue_depth: 32,
            workers: 1,
            timed: None,
        },
        Arc::new(SimExecutor),
        wl,
    )
    .unwrap();
    let j = legacy.stats.to_json();
    assert_eq!(keys(&j), LEGACY_TOP, "legacy top-level keys moved");
    let Json::Obj(top) = &j else { unreachable!() };
    let Json::Obj(models) = &top["models"] else {
        panic!("models is not an object")
    };
    for (name, mj) in models {
        assert_eq!(keys(mj), LEGACY_MODEL, "legacy keys moved for {name}");
    }

    // timed mode: the same surface plus exactly `timed` at the top and
    // `shed` per model
    let knee = knee_rps(&reg);
    let tcfg = TrafficConfig {
        rate_rps: knee,
        slo_s: 10.0 / knee,
        ..Default::default()
    };
    let wl = bursty_workload(&reg.models(), 200, 5, &tcfg);
    let timed = serve(
        &reg,
        &timed_cfg(Policy::EdfShed),
        Arc::new(SimExecutor),
        wl,
    )
    .unwrap();
    let j = timed.stats.to_json();
    let mut want_top: Vec<String> =
        LEGACY_TOP.iter().map(|k| k.to_string()).collect();
    want_top.push("timed".to_string());
    want_top.sort();
    assert_eq!(keys(&j), want_top, "timed top-level keys moved");
    let Json::Obj(top) = &j else { unreachable!() };
    assert_eq!(keys(&top["timed"]), TIMED_BLOCK, "timed block keys moved");
    let mut want_model: Vec<String> =
        LEGACY_MODEL.iter().map(|k| k.to_string()).collect();
    want_model.push("shed".to_string());
    want_model.sort();
    let Json::Obj(models) = &top["models"] else {
        panic!("models is not an object")
    };
    for (name, mj) in models {
        assert_eq!(keys(mj), want_model, "timed keys moved for {name}");
    }
}

#[test]
fn edf_beats_round_robin_on_the_strict_tier() {
    // the scheduling win the traffic bench gates in CI, pinned at test
    // scale: on an overloaded bursty trace, deadline-aware formation
    // pulls the strict tier's tail latency below the deadline-blind
    // baseline without giving up any completed work
    let reg = bench_registry();
    let knee = knee_rps(&reg);
    let tcfg = TrafficConfig {
        rate_rps: 1.5 * knee,
        slo_s: 20.0 / knee,
        ..Default::default()
    };
    let wl = bursty_workload(&reg.models(), 2000, 42, &tcfg);
    let run = |policy| {
        serve(&reg, &timed_cfg(policy), Arc::new(SimExecutor), wl.clone())
            .unwrap()
    };
    let rr = run(Policy::RoundRobin);
    let edf = run(Policy::Edf);
    let tr = rr.stats.timed.as_ref().unwrap();
    let te = edf.stats.timed.as_ref().unwrap();
    assert!(te.tier0_completed > 0, "trace must exercise the strict tier");
    assert!(
        te.tier0_p99_s < tr.tier0_p99_s,
        "EDF tier-0 p99 {:.1} ms !< RR tier-0 p99 {:.1} ms",
        te.tier0_p99_s * 1e3,
        tr.tier0_p99_s * 1e3
    );
    assert!(
        te.tier0_misses <= tr.tier0_misses,
        "EDF tier-0 misses {} > RR {}",
        te.tier0_misses,
        tr.tier0_misses
    );
    // neither policy sheds: the served set is identical, only the order
    // (and therefore the response times) differs
    assert_eq!(rr.stats.completed, 2000);
    assert_eq!(edf.stats.completed, 2000);
    assert_eq!(rr.stats.workload_digest, edf.stats.workload_digest);
}

//! Property tests for the compiled-plan serialization
//! (`coordinator::plan`): serialize → parse → re-serialize round-trips
//! must preserve the schedules and the partition exactly, and malformed
//! plans must be rejected with errors rather than garbage schedules.

use ago::coordinator::plan::{from_json, to_json};
use ago::coordinator::{compile, CompileConfig};
use ago::device::DeviceProfile;
use ago::ensure;
use ago::models::{build, InputShape, ModelId};
use ago::util::propkit::forall;
use ago::util::Json;

#[test]
fn roundtrip_preserves_schedules_and_partition() {
    // random compile configs over the model zoo; every plan must survive
    // serialize → parse → re-serialize bit-for-bit in structure
    forall(6, |rng| {
        let model = *rng.choose(&[ModelId::Mbn, ModelId::Sqn, ModelId::Bt]);
        let g = build(model, InputShape::Small);
        let m = compile(&g, &CompileConfig {
            budget: 150 + rng.range(0, 150),
            seed: rng.range(1, 1 << 20) as u64,
            workers: 2,
            ..CompileConfig::new(if rng.chance(0.5) {
                DeviceProfile::kirin990()
            } else {
                DeviceProfile::qsd810()
            })
        });
        let j = to_json(&m, model.name(), "dev");
        let text = j.pretty();
        let j2 = Json::parse(&text).map_err(|e| e.to_string())?;
        // re-serialize: the parsed document must render identically
        ensure!(j2 == j, "parse(pretty(j)) != j for {}", model.name());
        ensure!(j2.pretty() == text, "re-serialization drifted");
        let plan = from_json(&j2).map_err(|e| e.to_string())?;
        ensure!(
            plan.partition.assign == m.partition.assign,
            "partition drifted: {:?} vs {:?}",
            plan.partition.assign,
            m.partition.assign
        );
        // FusionGroup/Schedule derive PartialEq: exact structural match
        ensure!(
            plan.schedules == m.schedules,
            "schedules drifted for {}",
            model.name()
        );
        Ok(())
    });
}

#[test]
fn unknown_group_kind_is_an_error() {
    let text = r#"{
        "assign": [0, 0],
        "schedules": [[{
            "ops": [0, 1],
            "kind": "warp",
            "tile": [1, 1, 8],
            "layout": "nhwc",
            "vec": 8, "unroll": 4, "threads": 2
        }]]
    }"#;
    let j = Json::parse(text).unwrap();
    let err = from_json(&j).expect_err("unknown kind must be rejected");
    assert!(
        err.to_string().contains("unknown group kind"),
        "unexpected error: {err:#}"
    );
}

#[test]
fn missing_tile_and_bad_ops_are_errors() {
    for bad in [
        // group with no tile
        r#"{"assign": [0], "schedules": [[{"ops": [0], "kind": "simple"}]]}"#,
        // tile of wrong arity
        r#"{"assign": [0], "schedules": [[{"ops": [0], "kind": "simple",
            "tile": [1, 1]}]]}"#,
        // non-numeric op id
        r#"{"assign": [0], "schedules": [[{"ops": ["x"], "kind": "simple",
            "tile": [1, 1, 1]}]]}"#,
    ] {
        let j = Json::parse(bad).unwrap();
        assert!(from_json(&j).is_err(), "accepted malformed plan: {bad}");
    }
}

//! Property tests for the compiled-plan serialization
//! (`coordinator::plan`): serialize → parse → re-serialize round-trips
//! must preserve the schedules and the partition exactly, and malformed
//! plans must be rejected with errors rather than garbage schedules.

use ago::coordinator::plan::{from_json, loaded_to_json, to_json};
use ago::coordinator::{compile, CompileConfig};
use ago::device::DeviceProfile;
use ago::ensure;
use ago::models::{build, InputShape, ModelId};
use ago::util::propkit::forall;
use ago::util::Json;

#[test]
fn roundtrip_preserves_schedules_and_partition() {
    // random compile configs over the model zoo; every plan must survive
    // serialize → parse → re-serialize bit-for-bit in structure
    forall(6, |rng| {
        let model = *rng.choose(&[ModelId::Mbn, ModelId::Sqn, ModelId::Bt]);
        let g = build(model, InputShape::Small);
        let m = compile(&g, &CompileConfig {
            budget: 150 + rng.range(0, 150),
            seed: rng.range(1, 1 << 20) as u64,
            workers: 2,
            ..CompileConfig::new(if rng.chance(0.5) {
                DeviceProfile::kirin990()
            } else {
                DeviceProfile::qsd810()
            })
        });
        let j = to_json(&m, model.name(), "dev");
        let text = j.pretty();
        let j2 = Json::parse(&text).map_err(|e| e.to_string())?;
        // re-serialize: the parsed document must render identically
        ensure!(j2 == j, "parse(pretty(j)) != j for {}", model.name());
        ensure!(j2.pretty() == text, "re-serialization drifted");
        let plan = from_json(&j2).map_err(|e| e.to_string())?;
        ensure!(
            plan.partition.assign == m.partition.assign,
            "partition drifted: {:?} vs {:?}",
            plan.partition.assign,
            m.partition.assign
        );
        // FusionGroup/Schedule derive PartialEq: exact structural match
        ensure!(
            plan.schedules == m.schedules,
            "schedules drifted for {}",
            model.name()
        );
        Ok(())
    });
}

#[test]
fn partition_search_provenance_roundtrips_bit_exactly() {
    // a cost-guided compile (K > 1) must carry its provenance through
    // serialize → load → re-serialize unchanged, and the absence of the
    // field (single-shot and pre-stage-pipeline plans) must load fine
    let g = build(ModelId::Sqn, InputShape::Small);
    let m = compile(&g, &CompileConfig {
        budget: 400,
        workers: 2,
        partition_candidates: 3,
        ..CompileConfig::new(DeviceProfile::kirin990())
    });
    let se = m.partition_search.as_ref().expect("K>1 records provenance");
    let j = to_json(&m, "sqn", "kirin990");
    let text = j.pretty();
    assert!(text.contains("partition_search"));
    assert!(text.contains("probe_scores_s"));
    let loaded = from_json(&Json::parse(&text).unwrap()).unwrap();
    let carried = loaded.partition_search.as_ref().unwrap();
    // scores survive as raw seconds, bit for bit
    let scores = carried
        .get("probe_scores_s")
        .and_then(|a| a.as_arr())
        .unwrap();
    assert_eq!(scores.len(), se.probe_scores.len());
    for (a, b) in scores.iter().zip(&se.probe_scores) {
        assert_eq!(a.as_f64().unwrap().to_bits(), b.to_bits());
    }
    // the winning config decodes back through ClusterConfig::from_json
    let cc = ago::partition::ClusterConfig::from_json(
        carried.get("chosen_config").unwrap(),
    )
    .unwrap();
    assert_eq!(cc, se.chosen_config);
    // load → re-serialize → load: bytes and provenance stable
    let re = loaded_to_json(&loaded).pretty();
    let loaded2 = from_json(&Json::parse(&re).unwrap()).unwrap();
    assert_eq!(loaded2.partition_search, loaded.partition_search);
    assert_eq!(loaded_to_json(&loaded2).pretty(), re);
    // plans without the field still load (and re-serialize without it)
    let mut single = m.clone();
    single.partition_search = None;
    let st = to_json(&single, "sqn", "kirin990").pretty();
    assert!(!st.contains("partition_search"));
    let ls = from_json(&Json::parse(&st).unwrap()).unwrap();
    assert!(ls.partition_search.is_none());
    assert!(!loaded_to_json(&ls).pretty().contains("partition_search"));
}

#[test]
fn unknown_group_kind_is_an_error() {
    let text = r#"{
        "assign": [0, 0],
        "schedules": [[{
            "ops": [0, 1],
            "kind": "warp",
            "tile": [1, 1, 8],
            "layout": "nhwc",
            "vec": 8, "unroll": 4, "threads": 2
        }]]
    }"#;
    let j = Json::parse(text).unwrap();
    let err = from_json(&j).expect_err("unknown kind must be rejected");
    assert!(
        err.to_string().contains("unknown group kind"),
        "unexpected error: {err:#}"
    );
}

#[test]
fn missing_tile_and_bad_ops_are_errors() {
    for bad in [
        // group with no tile
        r#"{"assign": [0], "schedules": [[{"ops": [0], "kind": "simple"}]]}"#,
        // tile of wrong arity
        r#"{"assign": [0], "schedules": [[{"ops": [0], "kind": "simple",
            "tile": [1, 1]}]]}"#,
        // non-numeric op id
        r#"{"assign": [0], "schedules": [[{"ops": ["x"], "kind": "simple",
            "tile": [1, 1, 1]}]]}"#,
    ] {
        let j = Json::parse(bad).unwrap();
        assert!(from_json(&j).is_err(), "accepted malformed plan: {bad}");
    }
}

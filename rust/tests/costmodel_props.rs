//! Property tests over the cost model and the reformer — the invariants
//! the tuner's search correctness rests on.

use ago::costmodel::{
    group_latency, schedule_latency, CostEvaluator, DirectEvaluator,
    MemoEvaluator,
};
use ago::device::DeviceProfile;
use ago::ensure;
use ago::graph::{Graph, OpKind, Shape, Subgraph};
use ago::reformer::{join_schedules, split};
use ago::tuner::legality::redundancy_factor;
use ago::tuner::schedule::{
    divisors, FusionGroup, GroupKind, Layout, Schedule, SubgraphView, Tile,
};
use ago::tuner::search::random_schedule;
use ago::util::propkit::forall;
use ago::util::Rng;

fn chain_graph(rng: &mut Rng) -> (Graph, SubgraphView) {
    // random chain of 3-10 ops with 1-4 complex ops
    let mut g = Graph::new("chain");
    let hw = *rng.choose(&[7usize, 14, 28]);
    let c = *rng.choose(&[8usize, 16, 32]);
    let s = Shape::nhwc(1, hw, hw, c);
    let n = rng.range(3, 11);
    let mut prev: Option<usize> = None;
    for i in 0..n {
        let kind = match rng.range(0, 5) {
            0 => OpKind::Pointwise,
            1 => OpKind::Depthwise { kh: 3, kw: 3, stride: 1 },
            2 => OpKind::BiasAdd,
            3 => OpKind::ReLU,
            _ => OpKind::Add,
        };
        let inputs: Vec<usize> = prev.into_iter().collect();
        let id = g.add(kind, &format!("n{i}"), s.clone(), c, &inputs);
        prev = Some(id);
    }
    let nodes: Vec<usize> = (0..g.len()).collect();
    let view = SubgraphView::new(&g, &Subgraph { id: 0, nodes });
    (g, view)
}

#[test]
fn latency_is_positive_and_finite_for_any_schedule() {
    forall(200, |rng| {
        let (g, view) = chain_graph(rng);
        let dev = if rng.chance(0.5) {
            DeviceProfile::kirin990()
        } else {
            DeviceProfile::qsd810()
        };
        let s = random_schedule(&g, &view, rng, true);
        let lat = schedule_latency(&g, &s, &dev);
        ensure!(lat.is_finite() && lat > 0.0, "latency {lat}");
        Ok(())
    });
}

#[test]
fn memoized_evaluator_is_bit_identical_to_direct() {
    // the CostEvaluator seam's core contract: caching must be invisible
    // — cold, warm, and across schedules sharing groups, the memoized
    // path returns the exact f64 `schedule_latency` returns
    forall(120, |rng| {
        let (g, view) = chain_graph(rng);
        let dev = if rng.chance(0.5) {
            DeviceProfile::kirin990()
        } else {
            DeviceProfile::qsd810()
        };
        let mut memo = MemoEvaluator::new(&g, &dev);
        let mut direct = DirectEvaluator::new(&g, &dev);
        for _ in 0..6 {
            let s = random_schedule(&g, &view, rng, true);
            let raw = schedule_latency(&g, &s, &dev);
            let d = direct.evaluate_schedule(&s);
            let cold = memo.evaluate_schedule(&s);
            let warm = memo.evaluate_schedule(&s);
            ensure!(raw == d, "direct diverged: {raw} vs {d}");
            ensure!(raw == cold, "memo cold diverged: {raw} vs {cold}");
            ensure!(raw == warm, "memo warm diverged: {raw} vs {warm}");
            // group-level parity too
            for grp in &s.groups {
                let rg = group_latency(&g, grp, &dev);
                ensure!(memo.evaluate_group(grp) == rg, "group diverged");
            }
        }
        let st = memo.stats();
        ensure!(st.hits > 0, "warm re-evaluations never hit the cache");
        ensure!(direct.stats().hits == 0, "direct evaluator cannot cache");
        Ok(())
    });
}

#[test]
fn redundancy_factor_at_least_one_and_free_at_whole_tile() {
    forall(200, |rng| {
        let mut g = Graph::new("t");
        let hw = rng.range(4, 30);
        let c = *rng.choose(&[8usize, 16, 64]);
        let s = Shape::nhwc(1, hw, hw, c);
        let i = g.add(OpKind::Pad, "in", s.clone(), 0, &[]);
        let kind = match rng.range(0, 3) {
            0 => OpKind::Depthwise { kh: 3, kw: 3, stride: 1 },
            1 => OpKind::Pointwise,
            _ => OpKind::Conv2d { kh: 3, kw: 3, stride: 1 },
        };
        let d = g.add(kind, "down", s.clone(), c, &[i]);
        let tile = Tile {
            th: *rng.choose(&divisors(hw)),
            tw: *rng.choose(&divisors(hw)),
            tc: *rng.choose(&divisors(c)),
        };
        let f = redundancy_factor(&g, d, &tile);
        ensure!(f >= 1.0, "factor {f} < 1");
        // whole tile is always redundancy-free
        let whole = Tile { th: hw, tw: hw, tc: c };
        let fw = redundancy_factor(&g, d, &whole);
        ensure!((fw - 1.0).abs() < 1e-9, "whole-tile factor {fw}");
        // monotone-ish: the whole tile is never worse than a random tile
        ensure!(fw <= f + 1e-9, "whole {fw} > tiled {f}");
        Ok(())
    });
}

#[test]
fn more_redundant_tiling_never_cheaper() {
    forall(100, |rng| {
        let mut g = Graph::new("t");
        let hw = 28;
        let c = 64;
        let s = Shape::nhwc(1, hw, hw, c);
        let i = g.add(OpKind::Pad, "in", s.clone(), 0, &[]);
        let pw = g.add(OpKind::Pointwise, "pw", s.clone(), c, &[i]);
        let dw = g.add(OpKind::Depthwise { kh: 3, kw: 3, stride: 1 }, "dw",
                       s, 0, &[pw]);
        let dev = DeviceProfile::kirin990();
        let mk = |tile| FusionGroup {
            ops: vec![i, pw, dw],
            kind: GroupKind::Intensive,
            tile,
            vec: 8,
            unroll: 4,
            threads: 4,
            layout: Layout::Nhwc,
        };
        // shrinking the spatial tile of a dw-downstream intensive group
        // strictly increases upstream recomputation
        let tc = *rng.choose(&[4usize, 8, 16]);
        let big = mk(Tile { th: 28, tw: 28, tc });
        let small_t = *rng.choose(&[1usize, 2, 4, 7]);
        let small = mk(Tile { th: small_t, tw: small_t, tc });
        let lb = group_latency(&g, &big, &dev);
        let ls = group_latency(&g, &small, &dev);
        ensure!(lb <= ls + 1e-12, "redundant tile cheaper: {lb} vs {ls}");
        Ok(())
    });
}

#[test]
fn split_then_join_preserves_op_cover() {
    forall(150, |rng| {
        let (g, view) = chain_graph(rng);
        let minis = split(&view, &g);
        for m in &minis {
            ensure!(m.complex.len() <= 1,
                    "mini with {} complex ops", m.complex.len());
        }
        let scheds: Vec<Schedule> = minis
            .iter()
            .map(|m| random_schedule(&g, m, rng, true))
            .collect();
        let joined = join_schedules(scheds);
        let mut covered: Vec<usize> = joined
            .groups
            .iter()
            .flat_map(|gr| gr.ops.clone())
            .collect();
        covered.sort_unstable();
        ensure!(covered == view.order,
                "join lost ops: {covered:?} vs {:?}", view.order);
        Ok(())
    });
}

#[test]
fn joined_schedule_cost_is_sum_plus_layout_conversions() {
    // join concatenates groups; group costs are independent, so the
    // composed cost can only exceed the sum of mini costs by the layout
    // conversion passes at the newly visible mini boundaries — and is
    // exactly equal when every group uses the same layout.
    forall(80, |rng| {
        let (g, view) = chain_graph(rng);
        let dev = DeviceProfile::qsd810();
        let minis = split(&view, &g);
        let mut scheds: Vec<Schedule> = minis
            .iter()
            .map(|m| random_schedule(&g, m, rng, true))
            .collect();
        let parts: f64 = scheds
            .iter()
            .map(|s| schedule_latency(&g, s, &dev))
            .sum();
        let joined = join_schedules(scheds.clone());
        let total = schedule_latency(&g, &joined, &dev);
        ensure!(
            total >= parts - 1e-12 * parts.max(1.0),
            "join made cost vanish: {total} vs {parts}"
        );
        // uniform layout => exact additivity
        for s in &mut scheds {
            for grp in &mut s.groups {
                grp.layout = Layout::Nhwc;
            }
        }
        let parts_u: f64 = scheds
            .iter()
            .map(|s| schedule_latency(&g, s, &dev))
            .sum();
        let joined_u = join_schedules(scheds);
        let total_u = schedule_latency(&g, &joined_u, &dev);
        ensure!(
            (total_u - parts_u).abs() < 1e-12 * parts_u.max(1.0),
            "uniform-layout join changed cost: {total_u} vs {parts_u}"
        );
        Ok(())
    });
}

#[test]
fn layout_mismatch_never_cheaper() {
    // flipping one group of a uniform-layout schedule to the other layout
    // adds conversion cost and/or compute penalty — never a free win for
    // a pw-dominated chain already in its preferred layout.
    forall(80, |rng| {
        let mut g = Graph::new("t");
        let s = Shape::nhwc(1, 14, 14, 32);
        let i = g.add(OpKind::Pad, "in", s.clone(), 0, &[]);
        let a = g.add(OpKind::Pointwise, "a", s.clone(), 32, &[i]);
        let b = g.add(OpKind::Pointwise, "b", s.clone(), 32, &[a]);
        let nodes = vec![i, a, b];
        let view = SubgraphView::new(&g, &Subgraph { id: 0, nodes });
        let dev = DeviceProfile::kirin990();
        let mut sch = random_schedule(&g, &view, rng, true);
        for grp in &mut sch.groups {
            grp.layout = Layout::Nhwc; // preferred for pointwise
        }
        let base = schedule_latency(&g, &sch, &dev);
        let gi = rng.range(0, sch.groups.len());
        sch.groups[gi].layout = Layout::Nchw;
        let flipped = schedule_latency(&g, &sch, &dev);
        ensure!(
            flipped >= base - 1e-15,
            "layout flip got cheaper: {flipped} vs {base}"
        );
        Ok(())
    });
}

#[test]
fn qsd_never_faster_than_kirin_on_same_schedule() {
    forall(100, |rng| {
        let (g, view) = chain_graph(rng);
        let s = random_schedule(&g, &view, rng, true);
        let lk = schedule_latency(&g, &s, &DeviceProfile::kirin990());
        let lq = schedule_latency(&g, &s, &DeviceProfile::qsd810());
        ensure!(lk <= lq * 1.001, "kirin {lk} slower than qsd {lq}");
        Ok(())
    });
}

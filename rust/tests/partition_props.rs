//! Property-based tests over the graph frontend: random DAGs in, the
//! CLUSTER/Relay invariants out. Uses the in-house propkit (no proptest
//! offline); failures print a reproducing seed.

use ago::ensure;
use ago::graph::{Graph, OpKind, Shape};
use ago::partition::{
    candidates, cluster, relay_partition, subgraph_weights, ClusterConfig,
    WeightParams,
};
use ago::util::propkit::forall;
use ago::util::Rng;

/// Random layered DAG with mixed op kinds (shapes kept consistent enough
/// for the partitioner: it only reads kinds + shapes, not data).
fn random_graph(rng: &mut Rng) -> Graph {
    let mut g = Graph::new("random");
    let n = rng.range(2, 60);
    let hw = *rng.choose(&[7usize, 14, 28]);
    let c = *rng.choose(&[8usize, 16, 32]);
    let s = Shape::nhwc(1, hw, hw, c);
    for i in 0..n {
        let kind = match rng.range(0, 10) {
            0 => OpKind::Conv2d { kh: 3, kw: 3, stride: 1 },
            1 => OpKind::Pointwise,
            2 => OpKind::Depthwise { kh: 3, kw: 3, stride: 1 },
            3 => OpKind::MatMul,
            4 => OpKind::Add,
            5 => OpKind::ReLU,
            6 => OpKind::Reshape,
            7 => OpKind::Transpose,
            8 => OpKind::BiasAdd,
            _ => OpKind::Mul,
        };
        // each node reads 0-2 random earlier nodes
        let mut inputs = Vec::new();
        if i > 0 {
            let k = rng.range(0, 3.min(i + 1));
            for _ in 0..k {
                inputs.push(rng.range(0, i));
            }
            inputs.sort_unstable();
            inputs.dedup();
        }
        g.add(kind, &format!("n{i}"), s.clone(), c, &inputs);
    }
    g
}

#[test]
fn cluster_output_is_acyclic_cover_under_threshold() {
    forall(150, |rng| {
        let g = random_graph(rng);
        let td = *rng.choose(&[50.0, 400.0, 2000.0, f64::INFINITY]);
        let cfg = ClusterConfig { td, weights: WeightParams::default() };
        let p = cluster(&g, cfg);
        ensure!(p.is_cover(&g), "not a cover");
        ensure!(p.is_acyclic(&g), "cyclic partition (td={td})");
        // threshold: multi-member groups stay under td
        let ws = subgraph_weights(&g, &p, cfg.weights);
        let mut sizes = vec![0usize; p.n_groups];
        for &a in &p.assign {
            sizes[a] += 1;
        }
        for (gid, &w) in ws.iter().enumerate() {
            ensure!(
                w < td || sizes[gid] == 1,
                "group {gid}: weight {w} >= td {td} with {} members",
                sizes[gid]
            );
        }
        Ok(())
    });
}

#[test]
fn cluster_schedule_exists_and_covers_groups() {
    forall(60, |rng| {
        let g = random_graph(rng);
        let p = cluster(&g, ClusterConfig::adaptive(&g));
        let sched = p.schedule(&g);
        ensure!(
            sched.len() == p.n_groups,
            "schedule misses groups: {} vs {}",
            sched.len(),
            p.n_groups
        );
        // schedule must be a valid topological order of the quotient
        let mut pos = vec![0usize; p.n_groups];
        for (i, &gid) in sched.iter().enumerate() {
            pos[gid] = i;
        }
        for (a, b) in p.quotient_edges(&g) {
            ensure!(pos[a] < pos[b], "schedule violates edge {a}->{b}");
        }
        Ok(())
    });
}

#[test]
fn relay_invariants_on_random_graphs() {
    forall(150, |rng| {
        let g = random_graph(rng);
        let p = relay_partition(&g);
        ensure!(p.is_cover(&g), "relay: not a cover");
        ensure!(p.is_acyclic(&g), "relay: cyclic");
        for (gid, &c) in p.complex_counts(&g).iter().enumerate() {
            ensure!(c <= 1, "relay group {gid} has {c} complex ops");
        }
        // movement ops are singletons
        let mut sizes = vec![0usize; p.n_groups];
        for &a in &p.assign {
            sizes[a] += 1;
        }
        for node in &g.nodes {
            if node.kind.is_data_movement() && !g.preds(node.id).is_empty()
            {
                ensure!(
                    sizes[p.assign[node.id]] == 1,
                    "movement op {} not a singleton",
                    node.id
                );
            }
        }
        Ok(())
    });
}

#[test]
fn cluster_never_coarser_than_relay_on_trivial_threshold() {
    // td = 0 means no merges at all: exactly n singleton groups
    forall(40, |rng| {
        let g = random_graph(rng);
        let p = cluster(
            &g,
            ClusterConfig { td: 0.0, weights: WeightParams::default() },
        );
        ensure!(p.n_groups == g.len(), "td=0 must yield singletons");
        Ok(())
    });
}

#[test]
fn candidates_are_acyclic_covers_and_deterministic() {
    // cost-guided partition search properties on random DAGs: every
    // generated candidate is an acyclic cover of all nodes (Theorem 1
    // machinery applies to each), candidate 0 is the base partition
    // verbatim, assignments are pairwise distinct, and generation is a
    // pure function of (graph, base, k)
    forall(60, |rng| {
        let g = random_graph(rng);
        let base = ClusterConfig::adaptive(&g);
        let k = rng.range(1, 7);
        let cands = candidates(&g, base, k);
        ensure!(!cands.is_empty() && cands.len() <= k.max(1),
                "bad candidate count {} for k {k}", cands.len());
        ensure!(
            cands[0].partition.assign == cluster(&g, base).assign,
            "candidate 0 is not the base partition"
        );
        for c in &cands {
            ensure!(c.partition.is_cover(&g), "{}: not a cover", c.label);
            ensure!(c.partition.is_acyclic(&g), "{}: cyclic", c.label);
        }
        for (i, a) in cands.iter().enumerate() {
            for b in &cands[i + 1..] {
                ensure!(
                    a.partition.assign != b.partition.assign,
                    "duplicate candidates {} / {}",
                    a.label,
                    b.label
                );
            }
        }
        let again = candidates(&g, base, k);
        ensure!(again.len() == cands.len(), "non-deterministic count");
        for (x, y) in cands.iter().zip(&again) {
            ensure!(x.label == y.label, "non-deterministic labels");
            ensure!(x.config == y.config, "non-deterministic configs");
            ensure!(
                x.partition.assign == y.partition.assign,
                "non-deterministic assignment for {}",
                x.label
            );
        }
        Ok(())
    });
}

#[test]
fn candidate_sweep_is_diverse_on_the_zoo() {
    use ago::models::{build, InputShape, ModelId};
    for m in ModelId::all() {
        let g = build(m, InputShape::Small);
        let cands = candidates(&g, ClusterConfig::adaptive(&g), 4);
        assert!(
            cands.len() >= 2,
            "{}: Td sweep produced no alternative partition",
            m.name()
        );
        // the sweep leans coarse: at least one candidate has fewer
        // subgraphs than the adaptive baseline
        assert!(
            cands[1..]
                .iter()
                .any(|c| c.partition.n_groups < cands[0].partition.n_groups),
            "{}: no coarser candidate",
            m.name()
        );
    }
}

#[test]
fn adaptive_td_merges_something_on_real_models() {
    use ago::models::{build, InputShape, ModelId};
    for m in ModelId::all() {
        for s in [InputShape::Small, InputShape::Large] {
            let g = build(m, s);
            let p = cluster(&g, ClusterConfig::adaptive(&g));
            assert!(p.is_acyclic(&g));
            assert!(
                p.n_groups < g.len(),
                "{}/{:?}: nothing merged",
                m.name(),
                s
            );
        }
    }
}

//! Property tests for canonical subgraph fingerprints and schedule
//! remapping — the PR 2 tentpole contract:
//!
//! 1. isomorphic subgraphs (same structure, permuted node ids) hash
//!    equal and verify as isomorphic;
//! 2. structurally distinct subgraphs on the seed models never collide
//!    into an unverifiable class (fingerprint equality ⟹ verified
//!    isomorphism there);
//! 3. `Schedule::remap` round-trips through canonical-index space and a
//!    remapped schedule covers the member exactly once with BIT-IDENTICAL
//!    evaluator latency — the property that makes tune-once-per-class
//!    sound.

use std::collections::HashMap;

use ago::costmodel::{CostEvaluator, MemoEvaluator};
use ago::device::DeviceProfile;
use ago::graph::fingerprint::{canonical_form, verify_isomorphism};
use ago::graph::{Graph, NodeId, OpKind, Shape};
use ago::models::{build, InputShape, ModelId};
use ago::partition::{cluster, ClusterConfig};
use ago::tuner::schedule::{Schedule, SubgraphView};
use ago::tuner::search::{tune_with_evaluator, SearchConfig};

/// pw -> (relu | dw) -> add diamond. `swap_branch_insertion` permutes
/// the node IDS of the two branches without changing the structure (the
/// add's input list keeps the same semantic order, so the cost model's
/// predecessor-order contract is preserved).
fn diamond_block(
    g: &mut Graph,
    input: NodeId,
    tag: &str,
    swap_branch_insertion: bool,
) -> Vec<NodeId> {
    let s = Shape::nhwc(1, 14, 14, 32);
    let pw = g.add(OpKind::Pointwise, &format!("{tag}.pw"), s.clone(), 32,
                   &[input]);
    let (relu, dw);
    if swap_branch_insertion {
        dw = g.add(OpKind::Depthwise { kh: 3, kw: 3, stride: 1 },
                   &format!("{tag}.dw"), s.clone(), 0, &[pw]);
        relu = g.add(OpKind::ReLU, &format!("{tag}.r"), s.clone(), 0, &[pw]);
    } else {
        relu = g.add(OpKind::ReLU, &format!("{tag}.r"), s.clone(), 0, &[pw]);
        dw = g.add(OpKind::Depthwise { kh: 3, kw: 3, stride: 1 },
                   &format!("{tag}.dw"), s.clone(), 0, &[pw]);
    }
    let add = g.add(OpKind::Add, &format!("{tag}.add"), s, 0, &[relu, dw]);
    vec![pw, relu, dw, add]
}

#[test]
fn permuted_node_ids_hash_equal_and_verify() {
    let mut g = Graph::new("t");
    let s = Shape::nhwc(1, 14, 14, 32);
    let i = g.add(OpKind::Pad, "in", s, 0, &[]);
    let b1 = diamond_block(&mut g, i, "a", false);
    let b2 = diamond_block(&mut g, *b1.last().unwrap(), "b", true);
    let (c1, c2) = (canonical_form(&g, &b1), canonical_form(&g, &b2));
    assert_eq!(
        c1.fingerprint, c2.fingerprint,
        "id permutation must not change the fingerprint"
    );
    assert!(verify_isomorphism(&g, &c1, &c2));
    assert!(verify_isomorphism(&g, &c2, &c1));
    // canonical orders put corresponding nodes at the same positions
    for (a, b) in c1.order.iter().zip(&c2.order) {
        assert_eq!(g.node(*a).kind, g.node(*b).kind);
    }
}

/// Classes on the seed models are sound: fingerprint-equal pairs always
/// pass exact isomorphism verification, and dedup actually happens where
/// the zoo repeats blocks.
#[test]
fn seed_model_classes_verify_and_dedup() {
    let mut any_dedup = false;
    for m in [ModelId::Mbn, ModelId::Sqn, ModelId::Mnsn] {
        let g = build(m, InputShape::Small);
        let p = cluster(&g, ClusterConfig::adaptive(&g));
        let views = SubgraphView::all(&g, &p);
        let canon: Vec<_> = views
            .iter()
            .filter(|v| !v.is_empty())
            .map(|v| canonical_form(&g, &v.order))
            .collect();
        let mut distinct = std::collections::BTreeSet::new();
        for i in 0..canon.len() {
            distinct.insert(canon[i].fingerprint);
            for j in (i + 1)..canon.len() {
                if canon[i].fingerprint == canon[j].fingerprint {
                    any_dedup = true;
                    assert!(
                        verify_isomorphism(&g, &canon[i], &canon[j]),
                        "{}: fingerprint collision between non-isomorphic \
                         subgraphs {i} and {j}",
                        m.name()
                    );
                } else {
                    // distinct fingerprints must not verify — otherwise
                    // the hash is splitting a real class
                    assert!(
                        !verify_isomorphism(&g, &canon[i], &canon[j]),
                        "{}: isomorphic subgraphs {i}/{j} hashed apart",
                        m.name()
                    );
                }
            }
        }
        assert!(distinct.len() > 1, "{}: degenerate hashing", m.name());
    }
    assert!(any_dedup, "seed zoo should contain repeated blocks");
}

fn canon_to_ids(order: &[NodeId]) -> HashMap<NodeId, NodeId> {
    order.iter().copied().enumerate().collect()
}

fn ids_to_canon(order: &[NodeId]) -> HashMap<NodeId, NodeId> {
    order.iter().copied().enumerate().map(|(i, v)| (v, i)).collect()
}

#[test]
fn remap_roundtrips_and_preserves_evaluator_latency() {
    let dev = DeviceProfile::kirin990();
    let g = build(ModelId::Mbn, InputShape::Small);
    let p = cluster(&g, ClusterConfig::adaptive(&g));
    let views = SubgraphView::all(&g, &p);
    let canon: Vec<_> =
        views.iter().map(|v| canonical_form(&g, &v.order)).collect();
    // group into verified classes
    let mut classes: Vec<Vec<usize>> = Vec::new();
    for i in 0..views.len() {
        if views[i].is_empty() {
            continue;
        }
        let mut placed = false;
        for cls in classes.iter_mut() {
            if canon[cls[0]].fingerprint == canon[i].fingerprint
                && verify_isomorphism(&g, &canon[cls[0]], &canon[i])
            {
                cls.push(i);
                placed = true;
                break;
            }
        }
        if !placed {
            classes.push(vec![i]);
        }
    }
    let mut checked_members = 0;
    for cls in classes.iter().filter(|c| c.len() >= 2) {
        let rep = cls[0];
        // tune the representative briefly
        let mut evaluator = MemoEvaluator::new(&g, &dev);
        let cfg = SearchConfig { budget: 200, ..Default::default() };
        let r = tune_with_evaluator(&g, &views[rep], &cfg, None,
                                    &mut evaluator);
        // rep -> canonical -> rep is the identity
        let canonical = r.best.remap(&ids_to_canon(&canon[rep].order))
            .expect("rep ops are members");
        let back = canonical.remap(&canon_to_ids(&canon[rep].order))
            .expect("canonical indices in range");
        assert_eq!(back, r.best, "canonical round-trip must be identity");
        for &m in &cls[1..] {
            let mut s: Schedule = canonical
                .remap(&canon_to_ids(&canon[m].order))
                .expect("canonical indices in range");
            // verified isomorphism: the legality re-check finds nothing
            assert_eq!(s.revalidate_legality(&g), 0);
            // coverage: every member op exactly once
            let mut covered: Vec<NodeId> = s
                .groups
                .iter()
                .flat_map(|grp| grp.ops.clone())
                .collect();
            covered.sort_unstable();
            let mut expect = views[m].order.clone();
            expect.sort_unstable();
            assert_eq!(covered, expect, "remap broke the op cover");
            // bit-identical latency on the member
            let mut member_eval = MemoEvaluator::new(&g, &dev);
            let lat = member_eval.evaluate_schedule(&s);
            assert_eq!(
                lat, r.best_latency,
                "remapped member must price identically to the rep"
            );
            checked_members += 1;
        }
    }
    assert!(checked_members > 0, "MBN must have a multi-member class");
}

#[test]
fn remap_rejects_foreign_maps() {
    let mut g = Graph::new("t");
    let s = Shape::nhwc(1, 8, 8, 8);
    let i = g.add(OpKind::Pad, "in", s.clone(), 0, &[]);
    let members = diamond_block(&mut g, i, "x", false);
    let cf = canonical_form(&g, &members);
    let mut evaluator = MemoEvaluator::new(&g, &DeviceProfile::qsd810());
    let cfg = SearchConfig { budget: 50, ..Default::default() };
    let view = SubgraphView {
        order: cf.order.clone(),
        complex: cf
            .order
            .iter()
            .copied()
            .filter(|&v| g.node(v).kind.is_complex())
            .collect(),
    };
    let r = tune_with_evaluator(&g, &view, &cfg, None, &mut evaluator);
    // a map that misses ops is a cache miss (None), never a panic
    let partial: HashMap<NodeId, NodeId> =
        [(members[0], 0)].into_iter().collect();
    assert!(r.best.remap(&partial).is_none());
}

//! Fig. 11 — end-to-end inference on the Kirin 990 profile (same grid as
//! Fig. 10 on the high-end device).

use ago::device::DeviceProfile;
use ago::experiments::{bench_budget, e2e_rows, render_e2e};
use ago::models::{InputShape, ModelId};

fn main() {
    let dev = DeviceProfile::kirin990();
    let budget = bench_budget();
    println!("budget = {budget} evals\n");
    let rows = e2e_rows(
        &dev,
        budget,
        &ModelId::classical(),
        &[InputShape::Small, InputShape::Middle, InputShape::Large],
    );
    print!("{}", render_e2e(&rows, dev.name));
    println!(
        "\npaper (Fig. 11): avg 1.9x/2.1x/1.5x vs Torch Mobile; \
         2.6x/1.6x/1.1x vs Ansor across the three shapes"
    );
}

//! Fig. 10 — end-to-end inference on the Snapdragon 810 profile:
//! MBN/MNSN/SQN/SFN at small/middle/large input shapes, Torch-Mobile-like
//! hand library vs Ansor-like tuner vs AGO.
//!
//! `AGO_BENCH_BUDGET` scales the tuning budget (default 20000, the
//! paper's setting).

use ago::device::DeviceProfile;
use ago::experiments::{bench_budget, e2e_rows, render_e2e};
use ago::models::{InputShape, ModelId};

fn main() {
    let dev = DeviceProfile::qsd810();
    let budget = bench_budget();
    println!("budget = {budget} evals\n");
    let rows = e2e_rows(
        &dev,
        budget,
        &ModelId::classical(),
        &[InputShape::Small, InputShape::Middle, InputShape::Large],
    );
    print!("{}", render_e2e(&rows, dev.name));
    println!(
        "\npaper (Fig. 10): avg 1.5x/1.6x/1.8x vs Torch Mobile across the \
         three shapes; avg 1.2x vs Ansor on each"
    );
}

//! Serving throughput bench: the PR acceptance scenario, measured.
//!
//! Compiles two seed models through one shared TuningDb (the serve-side
//! warm-start path), then answers a 1k+ request mixed workload through
//! the batching scheduler with `SimExecutor`, asserting the acceptance
//! invariants on every run:
//!   - zero dropped requests
//!   - bit-identical stats across two runs at the same seed
//!   - batched (16) simulated throughput ≥ 2x the batch-size-1 config
//!
//! Writes `BENCH_serve.json` next to `BENCH_tuner.json` so serving
//! throughput is tracked PR-over-PR. `--quick` shrinks the compile
//! budget and workload for the CI smoke run; the assertions still hold.

use std::sync::Arc;
use std::time::Instant;

use ago::coordinator::{CompileConfig, TuningDb};
use ago::device::DeviceProfile;
use ago::models::{InputShape, ModelId};
use ago::serve::{
    mixed_workload, serve, PlanRegistry, ServeConfig, ServeOutcome,
    SimExecutor,
};
use ago::util::json::{num, obj, s};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let dev = DeviceProfile::kirin990();
    let cfg = CompileConfig {
        budget: if quick { 400 } else { 2000 },
        workers: 0,
        ..CompileConfig::new(dev)
    };

    // plans via the registry's warm-recompile path: one shared db, so
    // SQN's compile reuses whatever block structure MBN already tuned
    let mut db = TuningDb::new();
    let mut registry = PlanRegistry::new();
    let t0 = Instant::now();
    registry
        .ensure_model(ModelId::Mbn, InputShape::Small, &cfg, &mut db, None)
        .expect("compile MBN");
    registry
        .ensure_model(ModelId::Sqn, InputShape::Small, &cfg, &mut db, None)
        .expect("compile SQN");
    let compile_secs = t0.elapsed().as_secs_f64();
    println!(
        "compiled {:?} in {compile_secs:.2}s ({} db entries)",
        registry.models(),
        db.len()
    );

    let n = if quick { 1000 } else { 4000 };
    let seed = 42;
    let workload = mixed_workload(&registry.models(), n, seed);
    let run = |max_batch: usize| -> (ServeOutcome, f64) {
        let t0 = Instant::now();
        let out = serve(
            &registry,
            &ServeConfig {
                max_batch,
                queue_depth: 64,
                workers: 0,
                timed: None,
            },
            Arc::new(SimExecutor),
            workload.clone(),
        )
        .expect("serve");
        (out, t0.elapsed().as_secs_f64())
    };

    let (batched, wall_batched) = run(16);
    assert_eq!(batched.stats.completed, n, "requests went missing");
    assert_eq!(batched.stats.dropped, 0, "dropped requests");

    // determinism gate: a second run at the same seed must serialize
    // bit-identically
    let (again, _) = run(16);
    assert_eq!(
        batched.stats.to_json().pretty(),
        again.stats.to_json().pretty(),
        "stats are not bit-identical across runs at the same seed"
    );

    let (unbatched, wall_unbatched) = run(1);
    assert_eq!(unbatched.stats.completed, n);
    let rps_batched = batched.stats.throughput_rps();
    let rps_unbatched = unbatched.stats.throughput_rps();
    let speedup = rps_batched / rps_unbatched;
    assert!(
        speedup >= 2.0,
        "batched throughput {rps_batched:.0} rps < 2x unbatched \
         {rps_unbatched:.0} rps ({speedup:.2}x)"
    );

    let mean_batch = n as f64 / batched.stats.batches.max(1) as f64;
    println!(
        "{n} requests, 2 models: batch1 {rps_unbatched:.0} rps, batch16 \
         {rps_batched:.0} rps ({speedup:.2}x, mean batch {mean_batch:.1}, \
         {} stalls)",
        batched.stats.backpressure_stalls
    );
    for (name, m) in &batched.stats.per_model {
        println!(
            "  {name}: {} reqs / {} batches, p50 {:.3} ms, p99 {:.3} ms, \
             {:.0} rps",
            m.completed,
            m.batches,
            m.lat_p50_s * 1e3,
            m.lat_p99_s * 1e3,
            m.throughput_rps()
        );
    }
    println!(
        "wall: batched {wall_batched:.2}s, unbatched {wall_unbatched:.2}s \
         (scheduler overhead; simulated time is the throughput basis)"
    );

    let record = obj(vec![
        ("bench", s("serve_throughput")),
        ("quick", num(if quick { 1.0 } else { 0.0 })),
        ("models", s("MBN+SQN/small")),
        ("requests", num(n as f64)),
        ("seed", num(seed as f64)),
        ("compile_secs", num(compile_secs)),
        ("batch1_rps", num(rps_unbatched)),
        ("batch16_rps", num(rps_batched)),
        ("batched_speedup", num(speedup)),
        ("mean_batch", num(mean_batch)),
        ("batches", num(batched.stats.batches as f64)),
        ("backpressure_stalls",
         num(batched.stats.backpressure_stalls as f64)),
        ("dropped", num(batched.stats.dropped as f64)),
        ("serial_ms_batch16", num(batched.stats.serial_s * 1e3)),
        ("serial_ms_batch1", num(unbatched.stats.serial_s * 1e3)),
        ("wall_secs_batch16", num(wall_batched)),
        ("wall_secs_batch1", num(wall_unbatched)),
    ]);
    std::fs::write("BENCH_serve.json", record.pretty())
        .expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");
}

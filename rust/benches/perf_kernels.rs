//! L3 perf bench: fused micro-kernel execution (`ago::kernels` + the
//! fused pricing switch). Three gates, all on the MODELED cost (the same
//! analytical roofline the tuner optimizes, so they are deterministic):
//!
//! 1. **per-pattern traffic collapse** — a streaming-dominated chain
//!    priced as ONE single-pass fused group vs one pass per op. The
//!    exemplar fused-kernel measurements this models land at 1.04x-1.13x
//!    end-to-end, so the streaming/reduction chains are gated >= 1.04x
//!    (the modeled ratio is far higher — the chain stops paying a
//!    store+reload per op boundary); `Stencil` must be untouched to the
//!    bit (fusing passes does not change a compute-bound roofline).
//! 2. **seed-zoo acceptance** — every seed model is compiled UNFUSED,
//!    then its schedules are repriced under fused execution: never worse
//!    on any model (pointwise dominance), strictly lower on >= 2 (the
//!    issue's bar; in practice every model has single-pass groups), and
//!    bit-equal on every group where fusion is not selected.
//! 3. **probe-seeding** — `--probe-seed` (FullTune warm-started from the
//!    probe winners) stays within 5% of the cold full tune on every seed
//!    model. Seeding changes search trajectories, so exact equality is
//!    not expected; the recorded ratios track it PR-over-PR.
//!
//! `--quick` shrinks the compile budgets ~4x for the CI smoke run and
//! writes the same `BENCH_kernels.json` record.

use ago::coordinator::{compile, CompileConfig};
use ago::costmodel::{group_latency, group_latency_fused, schedule_latency,
                     schedule_latency_fused};
use ago::device::DeviceProfile;
use ago::graph::{Graph, NodeId, OpKind, Shape};
use ago::kernels::{classify_group, count_patterns, counts_line, Pattern};
use ago::models::{build, InputShape, ModelId};
use ago::tuner::schedule::{classify, FusionGroup, Layout, Schedule, Tile};
use ago::util::json::{num, obj, s, Json};

/// Pad source feeding a same-shape op chain; returns the chain's ids
/// (the source stays outside every group, so the first grouped op pays a
/// real external-input read).
fn chain(kinds: &[OpKind]) -> (Graph, Vec<NodeId>) {
    let mut g = Graph::new("chain");
    let sh = Shape::nhwc(1, 28, 28, 64);
    let src = g.add(OpKind::Pad, "src", sh.clone(), 0, &[]);
    let mut prev = src;
    let mut ops = Vec::new();
    for (i, k) in kinds.iter().enumerate() {
        let id = g.add(k.clone(), &format!("n{i}"), sh.clone(), 64, &[prev]);
        ops.push(id);
        prev = id;
    }
    (g, ops)
}

fn group(g: &Graph, ops: Vec<NodeId>) -> FusionGroup {
    FusionGroup {
        kind: classify(g, &ops, false),
        ops,
        tile: Tile { th: 4, tw: 28, tc: 16 },
        vec: 8,
        unroll: 4,
        threads: 4,
        layout: Layout::Nhwc,
    }
}

/// (unfused per-op-pass latency, fused single-pass latency) for the
/// whole chain as one group vs one group per op.
fn fused_vs_per_op(g: &Graph, ops: &[NodeId], dev: &DeviceProfile)
                   -> (f64, f64, Pattern) {
    let whole = group(g, ops.to_vec());
    let pat = classify_group(g, &whole);
    let fused = Schedule { groups: vec![whole] };
    let per_op = Schedule {
        groups: ops.iter().map(|&v| group(g, vec![v])).collect(),
    };
    (
        schedule_latency(g, &per_op, dev),
        schedule_latency_fused(g, &fused, dev, true),
        pat,
    )
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let dev = DeviceProfile::kirin990();

    // --- per-pattern modeled traffic-collapse ratios --------------------
    let dw = OpKind::Depthwise { kh: 3, kw: 3, stride: 1 };
    let cases: [(&str, Vec<OpKind>, Pattern); 4] = [
        ("streaming",
         vec![OpKind::BiasAdd, OpKind::ReLU, OpKind::Add,
              OpKind::BiasAdd, OpKind::ReLU, OpKind::Add],
         Pattern::Streaming),
        ("reduction",
         vec![OpKind::BiasAdd, OpKind::ReLU, OpKind::Softmax, OpKind::Add],
         Pattern::Reduction),
        ("pipeline",
         vec![OpKind::Pointwise, OpKind::BiasAdd, OpKind::ReLU],
         Pattern::Pipeline),
        ("stencil", vec![dw.clone()], Pattern::Stencil),
    ];
    let mut ratio_rows: Vec<(&str, Json)> = Vec::new();
    let mut ratios = std::collections::BTreeMap::new();
    for (name, kinds, want) in &cases {
        let (g, ops) = chain(kinds);
        let (per_op, fused, pat) = fused_vs_per_op(&g, &ops, &dev);
        assert_eq!(pat, *want, "{name}: classified {pat:?}");
        let ratio = per_op / fused;
        println!(
            "{name:>9}: per-op {:.1} us, fused {:.1} us -> {ratio:.2}x",
            per_op * 1e6,
            fused * 1e6
        );
        ratio_rows.push((*name, num(ratio)));
        ratios.insert(*name, ratio);
    }
    // the issue's gate, anchored to the exemplar's measured 1.04x floor:
    // single-pass patterns on streaming-dominated chains must collapse
    // real modeled traffic, not round to noise
    assert!(
        ratios["streaming"] >= 1.04,
        "streaming fused ratio {} < 1.04x",
        ratios["streaming"]
    );
    assert!(
        ratios["reduction"] >= 1.04,
        "reduction fused ratio {} < 1.04x",
        ratios["reduction"]
    );
    assert!(
        ratios["pipeline"] > 1.0,
        "pipeline fusion gained nothing: {}",
        ratios["pipeline"]
    );
    // stencil: a bare complex op is the same single pass either way
    {
        let (g, ops) = chain(std::slice::from_ref(&dw));
        let grp = group(&g, ops);
        assert_eq!(
            group_latency_fused(&g, &grp, &dev, true).to_bits(),
            group_latency(&g, &grp, &dev).to_bits(),
            "stencil pricing moved under the fused switch"
        );
    }

    // --- seed-zoo acceptance: reprice every model's unfused plan -------
    let model_budget = if quick { 500 } else { 2000 };
    let mut strict_wins = 0usize;
    let mut model_rows: Vec<(&str, Json)> = Vec::new();
    for m in ModelId::all() {
        let g = build(m, InputShape::Small);
        let cfg = CompileConfig {
            budget: model_budget,
            ..CompileConfig::new(dev.clone())
        };
        let out = compile(&g, &cfg);
        let mut base = 0.0f64;
        let mut fused = 0.0f64;
        for sch in &out.schedules {
            for grp in &sch.groups {
                let l = group_latency(&g, grp, &dev);
                let f = group_latency_fused(&g, grp, &dev, true);
                // dominance per group; bit-equality where fusion is not
                // selected (Stencil keeps the per-op-pass model)
                assert!(f <= l, "{}: fused group {f} > per-op {l}", m.name());
                if !classify_group(&g, grp).single_pass() {
                    assert_eq!(
                        f.to_bits(),
                        l.to_bits(),
                        "{}: stencil group repriced",
                        m.name()
                    );
                }
            }
            base += schedule_latency_fused(&g, sch, &dev, false);
            fused += schedule_latency_fused(&g, sch, &dev, true);
        }
        assert!(
            fused <= base,
            "{}: fused repricing worse ({fused} vs {base})",
            m.name()
        );
        if fused < base {
            strict_wins += 1;
        }
        println!(
            "{:>5}/small: per-op {:.3} ms -> fused {:.3} ms ({:.2}x)",
            m.name(),
            base * 1e3,
            fused * 1e3,
            base / fused
        );
        model_rows.push((
            m.name(),
            obj(vec![
                ("per_op_ms", num(base * 1e3)),
                ("fused_ms", num(fused * 1e3)),
                ("speedup", num(base / fused)),
            ]),
        ));
    }
    assert!(
        strict_wins >= 2,
        "fused pricing strictly improved only {strict_wins}/6 models"
    );

    // --- fused compile: pattern census on MBN ---------------------------
    let mbn = build(ModelId::Mbn, InputShape::Small);
    let fused_cfg = CompileConfig {
        budget: model_budget,
        fused: true,
        ..CompileConfig::new(dev.clone())
    };
    let fout = compile(&mbn, &fused_cfg);
    let counts = count_patterns(&mbn, &fout.schedules);
    let n_groups: usize =
        fout.schedules.iter().map(|s| s.groups.len()).sum();
    assert_eq!(counts.iter().sum::<usize>(), n_groups);
    assert!(
        fout.patterns.is_some(),
        "fused compile must tag subgraph patterns"
    );
    println!("MBN/small fused compile: {}", counts_line(&counts));

    // --- probe-informed full tune vs cold, whole seed zoo ---------------
    let probe_budget = if quick { 400 } else { 1600 };
    let mut seed_rows: Vec<(&str, Json)> = Vec::new();
    for m in ModelId::all() {
        let g = build(m, InputShape::Small);
        let base_cfg = CompileConfig {
            budget: probe_budget,
            partition_candidates: 4,
            ..CompileConfig::new(dev.clone())
        };
        let cold = compile(&g, &base_cfg);
        let seeded_cfg = CompileConfig { probe_seed: true, ..base_cfg };
        let seeded = compile(&g, &seeded_cfg);
        let ratio = seeded.total_latency / cold.total_latency;
        println!(
            "{:>5}/small probe-seed: cold {:.3} ms, seeded {:.3} ms \
             ({ratio:.3}x)",
            m.name(),
            cold.latency_ms(),
            seeded.latency_ms()
        );
        // seeding reshuffles the FullTune trajectory, so demand
        // near-never-worse rather than bit-equality; the ratio is
        // deterministic and recorded below for PR-over-PR tracking
        assert!(
            ratio <= 1.05,
            "{}: probe-seeded compile {ratio:.3}x worse than cold",
            m.name()
        );
        seed_rows.push((m.name(), num(ratio)));
    }

    // perf trajectory record
    let record = obj(vec![
        ("bench", s("perf_kernels")),
        ("quick", num(if quick { 1.0 } else { 0.0 })),
        ("model_budget", num(model_budget as f64)),
        ("probe_budget", num(probe_budget as f64)),
        // modeled single-pass collapse, per pattern (gate: streaming and
        // reduction >= 1.04x, stencil identically 1.0)
        ("traffic_ratio", obj(ratio_rows)),
        // unfused seed-zoo plans repriced under fused execution
        ("models", obj(model_rows)),
        ("fused_strict_wins", num(strict_wins as f64)),
        // per-pattern group census of a fused MBN compile
        (
            "mbn_patterns",
            obj(ago::kernels::ALL
                .iter()
                .zip(&counts)
                .map(|(p, &c)| (p.name(), num(c as f64)))
                .collect()),
        ),
        // probe-seeded FullTune vs cold (seeded/cold latency ratio)
        ("probe_seed_ratio", obj(seed_rows)),
    ]);
    std::fs::write("BENCH_kernels.json", record.pretty())
        .expect("write BENCH_kernels.json");
    println!("wrote BENCH_kernels.json");
}

//! Fig. 8 — tuning budget vs subgraph structure, and the Eq. (1) fit.
//!
//! The paper tunes subgraphs of increasing operator count at two tensor
//! shapes and shows (a) budget tracks tensor shape, not op count alone,
//! and (b) budget ≈ linear in the summed Eq. (1) weights. We measure
//! evals-to-stabilize of our tuner on the same templates and fit
//! weight -> budget with OLS.

use ago::device::DeviceProfile;
use ago::graph::{Graph, OpKind, Shape, Subgraph};
use ago::partition::weight::{node_weights, WeightParams};
use ago::tuner::schedule::SubgraphView;
use ago::tuner::search::{tune, SearchConfig};
use ago::util::benchkit::Table;
use ago::util::stats::linear_fit;

/// Build one template: conv followed by `extras` simple ops at the given
/// IOHW config. Returns (graph, view).
fn template(i: usize, o: usize, hw: usize, extras: &[OpKind])
    -> (Graph, SubgraphView)
{
    let mut g = Graph::new("fig8");
    let sin = Shape::nhwc(1, hw, hw, i);
    let sout = Shape::nhwc(1, hw, hw, o);
    let inp = g.add(OpKind::Pad, "in", sin, 0, &[]);
    let mut cur = g.add(OpKind::Conv2d { kh: 3, kw: 3, stride: 1 }, "conv",
                        sout.clone(), i, &[inp]);
    for (k, kind) in extras.iter().enumerate() {
        cur = g.add(kind.clone(), &format!("e{k}"), sout.clone(), 0,
                    &[cur]);
    }
    let nodes: Vec<usize> = (0..g.len()).collect();
    let view = SubgraphView::new(&g, &Subgraph { id: 0, nodes });
    (g, view)
}

fn main() {
    let dev = DeviceProfile::kirin990();
    let budget: usize = std::env::var("AGO_BENCH_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3000);
    let seeds: Vec<u64> = (1..=31).collect();

    let shapes = [(32usize, 64usize, 28usize), (64, 128, 14)];
    let extra_sets: [&[OpKind]; 4] = [
        &[],
        &[OpKind::Add],
        &[OpKind::Add, OpKind::ReLU],
        &[OpKind::Add, OpKind::ReLU, OpKind::Mul],
    ];

    let mut table = Table::new(&[
        "subgraph", "IOHW", "weight", "budget(avg)", "fit",
    ]);
    let mut ws = Vec::new();
    let mut bs = Vec::new();
    let mut rows = Vec::new();
    for (i, o, hw) in shapes {
        for extras in extra_sets {
            let (g, view) = template(i, o, hw, extras);
            let w: f64 =
                node_weights(&g, WeightParams::default()).iter().sum();
            let mut stab = 0.0;
            for &seed in &seeds {
                let cfg = SearchConfig {
                    budget,
                    stabilize_window: budget, // run the full budget
                    seed,
                    ..Default::default()
                };
                let r = tune(&g, &view, &dev, &cfg, None);
                // budget-to-stabilize: first eval whose best-so-far is
                // within 5% of the final best (smoother than the raw
                // last-improvement index)
                let target = r.best_latency * 1.05;
                let hit = r
                    .history
                    .iter()
                    .position(|&l| l <= target)
                    .unwrap_or(r.history.len());
                stab += hit as f64;
            }
            stab /= seeds.len() as f64;
            ws.push(w);
            bs.push(stab);
            rows.push((
                format!("conv+{}", extras.len()),
                format!("{i}/{o}/{hw}"),
                w,
                stab,
            ));
        }
    }
    let (a, b, r2) = linear_fit(&ws, &bs);
    for (name, iohw, w, stab) in rows {
        table.row(vec![
            name,
            iohw,
            format!("{w:.0}"),
            format!("{stab:.0}"),
            format!("{:.0}", a * w + b),
        ]);
    }
    table.print();
    println!(
        "\nEq.(1) OLS fit: budget = {a:.3} * weight + {b:.1}   (r^2 = {r2:.3})"
    );
    println!(
        "paper: 'we can almost perfectly fit the tuning budget with Eq. (1)'"
    );
}

//! Fig. 14 — subgraph weight distribution on MobileViT: AGO's weighted
//! clustering vs the Relay heuristic (log2-bin histogram + §VI-B summary
//! stats + Td-sensitivity sweep) — and, since the stage-pipeline rework,
//! the cost-guided partition-search gate: every seed-zoo model is
//! compiled single-shot (adaptive Td) and cost-guided
//! (`partition_candidates = 4`), and the run FAILS if cost-guided
//! selection is ever worse. Writes `BENCH_partition.json`.
//!
//! `--quick` keeps the full gate but skips nothing — the gate IS the
//! quick payload (budget 2000 on small shapes, deterministic seeds); the
//! full run additionally sweeps the probe overhead at the default
//! 20k-eval budget on one model.
//!
//! Calibration (python mirror, 5 seeds x 2 devices x budgets 1.2k/2k):
//! at the pinned bench config the sweep wins on mbn/mnsn/sfn/mvt
//! (ratios ~0.86/0.88/0.76/0.74) and PROBE_MARGIN keeps sqn/bt on the
//! adaptive baseline (ratio exactly 1.0) — geomean ~0.87.

use ago::coordinator::{compile, CompileConfig};
use ago::device::DeviceProfile;
use ago::models::{build, InputShape, ModelId};
use ago::partition::{
    cluster, relay_partition, ClusterConfig, PartitionReport, WeightParams,
};
use ago::util::benchkit::Table;
use ago::util::json::{arr, num, obj, s, Json};

fn geomean(xs: &[f64]) -> f64 {
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let g = build(ModelId::Mvt, InputShape::Large);
    let wp = WeightParams::default();
    let acfg = ClusterConfig::adaptive(&g);
    let ago = PartitionReport::build(&g, &cluster(&g, acfg), wp);
    let relay = PartitionReport::build(&g, &relay_partition(&g), wp);

    println!("MVT @ 224: {} operators\n", g.len());
    println!("{}", ago.summary("AGO  "));
    println!("{}\n", relay.summary("Relay"));

    let mut t = Table::new(&["weight bin", "AGO", "Relay"]);
    for (i, (a, r)) in ago.bins.iter().zip(&relay.bins).enumerate() {
        if *a > 0 || *r > 0 {
            t.row(vec![
                format!("[2^{i}, 2^{})", i + 1),
                a.to_string(),
                r.to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "\npaper (Fig. 14): AGO 82 subgraphs vs Relay 259; avg weight \
         437 vs 138; median 350 vs 23; Jain 0.55 vs 0.19; Relay has 105 \
         trivial subgraphs (<20)"
    );

    println!("\n== Td sensitivity (adaptive Td = {:.0}) ==", acfg.td);
    let mut t = Table::new(&["Td", "subgraphs", "Jain", "max complex"]);
    for f in [0.25, 0.5, 1.0, 2.0, 4.0] {
        let cfg = ClusterConfig { td: acfg.td * f, weights: wp };
        let p = cluster(&g, cfg);
        assert!(p.is_acyclic(&g));
        let r = PartitionReport::build(&g, &p, wp);
        t.row(vec![
            format!("{:.0}", cfg.td),
            r.n_subgraphs.to_string(),
            format!("{:.2}", r.jain),
            r.max_complex.to_string(),
        ]);
    }
    t.print();

    // ---- cost-guided partition search vs single-shot adaptive --------
    // The acceptance gate: K=4 candidates, kirin990, budget 2000, the
    // default seed. Cost-guided must never be worse than single-shot on
    // any seed model and strictly better on at least one.
    let budget = 2000usize;
    let dev = DeviceProfile::kirin990();
    println!(
        "\n== cost-guided partition search (K=4, budget {budget}, {}) ==",
        dev.name
    );
    let mut t = Table::new(&[
        "model", "single(ms)", "guided(ms)", "ratio", "chosen",
        "probe evals",
    ]);
    let mut ratios = Vec::new();
    let mut singles = Vec::new();
    let mut guided = Vec::new();
    let mut probe_total = 0usize;
    let mut strictly_better = 0usize;
    let mut models_json = Vec::new();
    for m in ModelId::all() {
        let graph = build(m, InputShape::Small);
        let base = CompileConfig {
            budget,
            ..CompileConfig::new(dev.clone())
        };
        let ss = compile(&graph, &base);
        let cg = compile(&graph, &CompileConfig {
            partition_candidates: 4,
            ..base
        });
        let se = cg
            .partition_search
            .as_ref()
            .expect("K=4 must record provenance");
        let ratio = cg.total_latency / ss.total_latency;
        // THE GATE: cost-guided selection is never worse than the
        // single-shot adaptive pipeline. When the probe margin keeps
        // candidate 0, the compile IS the single-shot compile (same
        // partition, same seeds, same budget), so equality is exact.
        assert!(
            cg.total_latency <= ss.total_latency * (1.0 + 1e-12),
            "{}: cost-guided {} worse than single-shot {}",
            m.name(),
            cg.total_latency,
            ss.total_latency
        );
        if se.chosen == 0 {
            assert_eq!(
                cg.total_latency, ss.total_latency,
                "{}: margin kept candidate 0 but latencies differ",
                m.name()
            );
        }
        if cg.total_latency < ss.total_latency {
            strictly_better += 1;
        }
        probe_total += se.probe_evals;
        ratios.push(ratio);
        singles.push(ss.total_latency);
        guided.push(cg.total_latency);
        t.row(vec![
            m.name().to_string(),
            format!("{:.4}", ss.latency_ms()),
            format!("{:.4}", cg.latency_ms()),
            format!("{ratio:.4}"),
            format!("[{}] {}", se.chosen, se.chosen_label),
            se.probe_evals.to_string(),
        ]);
        models_json.push((m, ss, cg, ratio));
    }
    t.print();
    let geo_ratio = geomean(&ratios);
    println!(
        "geomean ratio {geo_ratio:.4} ({strictly_better}/{} strictly \
         better, {probe_total} probe evals total = {:.2}x one budget)",
        ratios.len(),
        probe_total as f64 / budget as f64
    );
    assert!(
        strictly_better >= 1,
        "cost-guided selection never improved on any seed model"
    );
    // measured ~0.87 at this config; 0.95 leaves room for search-order
    // evolution without letting the capability regress to a no-op
    assert!(
        geo_ratio < 0.95,
        "cost-guided geomean ratio {geo_ratio:.4} lost its edge"
    );

    // probe overhead at the DEFAULT budget on one model (the overhead
    // fraction shrinks as the budget grows; the quick gate's budget is
    // small so its overhead multiple is the worst case)
    let default_overhead = if quick {
        None
    } else {
        let graph = build(ModelId::Mbn, InputShape::Small);
        let cg = compile(&graph, &CompileConfig {
            budget: 20_000,
            partition_candidates: 4,
            ..CompileConfig::new(dev.clone())
        });
        let se = cg.partition_search.as_ref().unwrap();
        let frac = se.probe_evals as f64 / 20_000.0;
        println!(
            "probe overhead at default budget (mbn, 20k): {} evals = \
             {frac:.2}x",
            se.probe_evals
        );
        Some(frac)
    };

    // ---- BENCH_partition.json ----------------------------------------
    let models: Vec<Json> = models_json
        .iter()
        .map(|(m, ss, cg, ratio)| {
            let se = cg.partition_search.as_ref().unwrap();
            obj(vec![
                ("model", s(m.name())),
                ("single_shot_ms", num(ss.latency_ms())),
                ("cost_guided_ms", num(cg.latency_ms())),
                ("ratio", num(*ratio)),
                ("chosen", num(se.chosen as f64)),
                ("chosen_label", s(&se.chosen_label)),
                ("probe_evals", num(se.probe_evals as f64)),
                ("probe_tasks", num(se.probe_tasks as f64)),
            ])
        })
        .collect();
    let mut fields = vec![
        ("bench", s("fig14_partition")),
        ("budget", num(budget as f64)),
        ("device", s(dev.name)),
        ("k", num(4.0)),
        ("geomean_single_shot_ms", num(geomean(&singles) * 1e3)),
        ("geomean_cost_guided_ms", num(geomean(&guided) * 1e3)),
        ("geomean_ratio", num(geo_ratio)),
        ("strictly_better", num(strictly_better as f64)),
        ("probe_evals_total", num(probe_total as f64)),
        (
            "probe_overhead_vs_budget",
            num(probe_total as f64 / budget as f64),
        ),
        ("models", arr(models)),
    ];
    if let Some(frac) = default_overhead {
        fields.push(("probe_overhead_at_default_budget", num(frac)));
    }
    std::fs::write("BENCH_partition.json", obj(fields).pretty())
        .expect("write BENCH_partition.json");
    println!("wrote BENCH_partition.json");
}

//! Fig. 14 — subgraph weight distribution on MobileViT: AGO's weighted
//! clustering vs the Relay heuristic. Reports the log2-bin histogram and
//! the §VI-B summary stats (count, average/median weight, Jain index,
//! trivial subgraphs), plus a Td-sensitivity sweep.

use ago::models::{build, InputShape, ModelId};
use ago::partition::{
    cluster, relay_partition, ClusterConfig, PartitionReport, WeightParams,
};
use ago::util::benchkit::Table;

fn main() {
    let g = build(ModelId::Mvt, InputShape::Large);
    let wp = WeightParams::default();
    let acfg = ClusterConfig::adaptive(&g);
    let ago = PartitionReport::build(&g, &cluster(&g, acfg), wp);
    let relay = PartitionReport::build(&g, &relay_partition(&g), wp);

    println!("MVT @ 224: {} operators\n", g.len());
    println!("{}", ago.summary("AGO  "));
    println!("{}\n", relay.summary("Relay"));

    let mut t = Table::new(&["weight bin", "AGO", "Relay"]);
    for (i, (a, r)) in ago.bins.iter().zip(&relay.bins).enumerate() {
        if *a > 0 || *r > 0 {
            t.row(vec![
                format!("[2^{i}, 2^{})", i + 1),
                a.to_string(),
                r.to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "\npaper (Fig. 14): AGO 82 subgraphs vs Relay 259; avg weight \
         437 vs 138; median 350 vs 23; Jain 0.55 vs 0.19; Relay has 105 \
         trivial subgraphs (<20)"
    );

    println!("\n== Td sensitivity (adaptive Td = {:.0}) ==", acfg.td);
    let mut t = Table::new(&["Td", "subgraphs", "Jain", "max complex"]);
    for f in [0.25, 0.5, 1.0, 2.0, 4.0] {
        let cfg = ClusterConfig { td: acfg.td * f, weights: wp };
        let p = cluster(&g, cfg);
        assert!(p.is_acyclic(&g));
        let r = PartitionReport::build(&g, &p, wp);
        t.row(vec![
            format!("{:.0}", cfg.td),
            r.n_subgraphs.to_string(),
            format!("{:.2}", r.jain),
            r.max_complex.to_string(),
        ]);
    }
    t.print();
}

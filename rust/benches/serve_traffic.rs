//! Traffic-replay bench: SLO-aware scheduling on the simulated clock.
//!
//! Compiles the two seed models (shared TuningDb, the serve warm-start
//! path), replays a deterministic bursty open-loop trace through every
//! scheduling policy, and gates the PR's scheduling claims on every run:
//!
//!   - strict-tier win: EDF tier-0 p99 strictly below round-robin's on
//!     an overloaded bursty trace, with no more tier-0 deadline misses
//!   - shedding contract: `edf-shed` accounts for every request
//!     (completed + shed == submitted) and the completed set meets its
//!     deadlines
//!   - below the knee: a calm trace under EDF misses zero deadlines and
//!     sheds nothing
//!   - hot-swap never-worse: with a 30%-faster recompile candidate the
//!     swap is accepted and simulated time/tail latency only improve,
//!     while the workload digest is unchanged (same requests answered)
//!   - determinism: back-to-back runs serialize bit-identically
//!
//! Appends a `traffic` record into `BENCH_serve.json` (merging with the
//! throughput bench's record when present) so the SLO numbers are
//! tracked PR-over-PR. `--quick` shrinks the compile budget and trace
//! length for the CI smoke run; every gate still fires.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use ago::coordinator::plan::LoadedPlan;
use ago::coordinator::{CompileConfig, TuningDb};
use ago::device::DeviceProfile;
use ago::models::{InputShape, ModelId};
use ago::serve::{
    bursty_workload, serve, HotSwapConfig, PlanRegistry, Policy, Request,
    ServeConfig, ServeOutcome, SimExecutor, TimedConfig, TrafficConfig,
};
use ago::util::json::{num, obj, s, Json};

/// Compile the two-model registry through one shared db. Deterministic,
/// so two calls build bit-identical registries — the hot-swap comparison
/// needs a fresh one (an accepted swap mutates the registry it serves).
fn build_registry(quick: bool) -> PlanRegistry {
    let dev = DeviceProfile::kirin990();
    let cfg = CompileConfig {
        budget: if quick { 400 } else { 2000 },
        workers: 0,
        ..CompileConfig::new(dev)
    };
    let mut db = TuningDb::new();
    let mut registry = PlanRegistry::new();
    registry
        .ensure_model(ModelId::Mbn, InputShape::Small, &cfg, &mut db, None)
        .expect("compile MBN");
    registry
        .ensure_model(ModelId::Sqn, InputShape::Small, &cfg, &mut db, None)
        .expect("compile SQN");
    registry
}

/// Mean batch-1 capacity, requests per second — the knee the traffic
/// rates are calibrated against.
fn knee_rps(reg: &PlanRegistry) -> f64 {
    let b1: Vec<f64> = reg
        .models()
        .iter()
        .map(|m| reg.get(m).unwrap().sim.batch_seconds(1))
        .collect();
    b1.len() as f64 / b1.iter().sum::<f64>()
}

fn run(
    reg: &PlanRegistry,
    policy: Policy,
    hot_swap: Option<HotSwapConfig>,
    wl: &[Request],
) -> ServeOutcome {
    serve(
        reg,
        &ServeConfig {
            max_batch: 8,
            queue_depth: 64,
            workers: 0,
            timed: Some(TimedConfig { policy, hot_swap }),
        },
        Arc::new(SimExecutor),
        wl.to_vec(),
    )
    .expect("serve")
}

/// The per-policy record: SLO observables the bench tracks PR-over-PR.
fn policy_record(out: &ServeOutcome) -> Json {
    let t = out.stats.timed.as_ref().expect("timed stats");
    let n = out.stats.requests.max(1) as f64;
    let c = out.stats.completed.max(1) as f64;
    obj(vec![
        ("completed", num(out.stats.completed as f64)),
        ("p50_ms", num(t.lat_p50_s * 1e3)),
        ("p99_ms", num(t.lat_p99_s * 1e3)),
        ("tier0_p99_ms", num(t.tier0_p99_s * 1e3)),
        ("deadline_miss_rate", num(t.deadline_misses as f64 / c)),
        ("tier0_misses", num(t.tier0_misses as f64)),
        ("shed_rate", num(t.shed as f64 / n)),
        ("sim_end_ms", num(t.sim_end_s * 1e3)),
    ])
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let t0 = Instant::now();
    let registry = build_registry(quick);
    let compile_secs = t0.elapsed().as_secs_f64();
    let knee = knee_rps(&registry);
    println!(
        "compiled {:?} in {compile_secs:.2}s, knee {knee:.0} rps",
        registry.models()
    );

    let n = if quick { 2000 } else { 6000 };
    let seed = 42;
    let rate = 1.5 * knee;
    let slo_s = 20.0 / knee;
    let tcfg = TrafficConfig { rate_rps: rate, slo_s, ..Default::default() };
    let wl = bursty_workload(&registry.models(), n, seed, &tcfg);

    let rr = run(&registry, Policy::RoundRobin, None, &wl);
    let edf = run(&registry, Policy::Edf, None, &wl);
    let shedding = run(&registry, Policy::EdfShed, None, &wl);
    let t_rr = rr.stats.timed.as_ref().unwrap();
    let t_edf = edf.stats.timed.as_ref().unwrap();
    let t_shed = shedding.stats.timed.as_ref().unwrap();
    for (name, out) in
        [("rr", &rr), ("edf", &edf), ("edf-shed", &shedding)]
    {
        let t = out.stats.timed.as_ref().unwrap();
        println!(
            "{name:>8}: p50 {:.1} ms, p99 {:.1} ms, tier-0 p99 {:.1} ms, \
             {} misses ({} tier-0), {} shed",
            t.lat_p50_s * 1e3,
            t.lat_p99_s * 1e3,
            t.tier0_p99_s * 1e3,
            t.deadline_misses,
            t.tier0_misses,
            t.shed
        );
    }

    // gate: deadline-aware formation wins the strict tier outright on an
    // overloaded bursty trace
    assert!(t_edf.tier0_completed > 0, "trace never hit the strict tier");
    assert!(
        t_edf.tier0_p99_s < t_rr.tier0_p99_s,
        "EDF tier-0 p99 {:.1} ms !< RR tier-0 p99 {:.1} ms",
        t_edf.tier0_p99_s * 1e3,
        t_rr.tier0_p99_s * 1e3
    );
    assert!(
        t_edf.tier0_misses <= t_rr.tier0_misses,
        "EDF tier-0 misses {} > RR {}",
        t_edf.tier0_misses,
        t_rr.tier0_misses
    );
    // neither RR nor EDF sheds, so both answer the same request set
    assert_eq!(rr.stats.workload_digest, edf.stats.workload_digest);

    // gate: explicit overload policy — everything is accounted for and
    // what completes, completes in time
    assert_eq!(
        shedding.stats.completed + shedding.shed.len(),
        n,
        "edf-shed lost requests"
    );
    assert_eq!(
        t_shed.deadline_misses, 0,
        "edf-shed let a completed request miss its deadline"
    );

    // gate: below the knee nothing misses and nothing is shed
    let calm_cfg = TrafficConfig {
        rate_rps: 0.4 * knee,
        slo_s,
        diurnal_amp: 0.3,
        burst_prob: 0.0,
        ..Default::default()
    };
    let calm_wl =
        bursty_workload(&registry.models(), n.min(2000), 7, &calm_cfg);
    let calm = run(&registry, Policy::Edf, None, &calm_wl);
    let t_calm = calm.stats.timed.as_ref().unwrap();
    assert_eq!(t_calm.deadline_misses, 0, "calm trace missed deadlines");
    assert_eq!(t_calm.shed, 0);
    println!(
        "    calm: p99 {:.1} ms, 0 misses below the knee",
        t_calm.lat_p99_s * 1e3
    );

    // gate: hot-swap never-worse. A 30%-faster candidate clears the
    // probe margin; the swapped run must only improve simulated time and
    // tail latency, answering the exact same request set.
    let candidates: BTreeMap<String, LoadedPlan> = registry
        .models()
        .iter()
        .map(|m| {
            let mut p = registry.get(m).unwrap().plan.clone();
            for l in &mut p.subgraph_latency {
                *l *= 0.7;
            }
            p.total_latency_ms *= 0.7;
            (m.clone(), p)
        })
        .collect();
    let hs = HotSwapConfig::new(Arc::new(move |m: &str| {
        candidates.get(m).cloned()
    }));
    let swapped = run(&build_registry(quick), Policy::Edf, Some(hs), &wl);
    let t_on = swapped.stats.timed.as_ref().unwrap();
    assert!(
        !t_on.swaps.is_empty() && t_on.swaps.iter().all(|sw| sw.accepted),
        "30%-faster candidates must be accepted: {:?}",
        t_on.swaps
    );
    assert!(
        swapped.stats.serial_s <= edf.stats.serial_s,
        "hot-swap made simulated time worse: {:.1} ms > {:.1} ms",
        swapped.stats.serial_s * 1e3,
        edf.stats.serial_s * 1e3
    );
    assert!(
        t_on.lat_p99_s <= t_edf.lat_p99_s,
        "hot-swap made p99 worse: {:.1} ms > {:.1} ms",
        t_on.lat_p99_s * 1e3,
        t_edf.lat_p99_s * 1e3
    );
    assert_eq!(
        swapped.stats.workload_digest, edf.stats.workload_digest,
        "hot-swap changed the served request set"
    );
    println!(
        "hot-swap: {} swaps accepted at {:.1} ms, p99 {:.1} -> {:.1} ms",
        t_on.swaps.len(),
        t_on.swaps[0].at_s * 1e3,
        t_edf.lat_p99_s * 1e3,
        t_on.lat_p99_s * 1e3
    );

    // gate: run-to-run determinism of the whole timed path
    let again = run(&registry, Policy::Edf, None, &wl);
    assert_eq!(
        edf.stats.to_json().pretty(),
        again.stats.to_json().pretty(),
        "timed stats are not bit-identical across runs"
    );

    let record = obj(vec![
        ("quick", num(if quick { 1.0 } else { 0.0 })),
        ("models", s("MBN+SQN/small")),
        ("requests", num(n as f64)),
        ("seed", num(seed as f64)),
        ("knee_rps", num(knee)),
        ("rate_rps", num(rate)),
        ("slo_ms", num(slo_s * 1e3)),
        ("rr", policy_record(&rr)),
        ("edf", policy_record(&edf)),
        ("edf_shed", policy_record(&shedding)),
        ("calm_edf", policy_record(&calm)),
        (
            "hot_swap",
            obj(vec![
                ("swaps_accepted", num(t_on.swaps.len() as f64)),
                ("swap_at_ms", num(t_on.swaps[0].at_s * 1e3)),
                ("p99_off_ms", num(t_edf.lat_p99_s * 1e3)),
                ("p99_on_ms", num(t_on.lat_p99_s * 1e3)),
                ("serial_off_ms", num(edf.stats.serial_s * 1e3)),
                ("serial_on_ms", num(swapped.stats.serial_s * 1e3)),
            ]),
        ),
    ]);
    // merge: the throughput bench writes a flat record into the same
    // file — keep it and add (or replace) the `traffic` section
    let merged = match std::fs::read_to_string("BENCH_serve.json")
        .ok()
        .and_then(|text| Json::parse(&text).ok())
    {
        Some(Json::Obj(mut m)) => {
            m.insert("traffic".to_string(), record);
            Json::Obj(m)
        }
        _ => obj(vec![("traffic", record)]),
    };
    std::fs::write("BENCH_serve.json", merged.pretty())
        .expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json (traffic section)");
}

//! Fig. 13 — ablation on four two-complex-operator subgraphs
//! (dw+dw, dw+pw, pw+dw, pw+pw) at batch 1 and 4:
//! AGO vs AGO-NI (no intensive fusion) vs AGO-NR (no reformer),
//! budget 2000 per the paper, both device profiles.
//!
//! A second section executes the corresponding AOT artifacts for REAL on
//! the PJRT CPU: fused pair kernel vs per-op chain wall-clock.

use std::time::Instant;

use ago::device::DeviceProfile;
use ago::experiments::fig13_table;
use ago::runtime::{Engine, TensorData};
use ago::util::Rng;

fn real_execution_section() {
    let dir = std::env::var("AGO_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let Ok(mut e) = Engine::new(&dir) else {
        println!("(artifacts not built; skipping real-execution section)");
        return;
    };
    println!("\n== real PJRT execution: fused kernel vs unfused chain ==");
    let mut rng = Rng::new(3);
    for b in [1usize, 4] {
        // pw->dw at 14x14, 32->64ch (catalog shapes)
        let fused = format!("fused_pw_dw_n{b}h14w14i32a64b64");
        let x = TensorData::random(&[b, 14, 14, 32], &mut rng);
        let w1 = TensorData::random(&[32, 64], &mut rng);
        let b1 = TensorData::random(&[64], &mut rng);
        let w2 = TensorData::random(&[3, 3, 1, 64], &mut rng);
        let b2 = TensorData::random(&[64], &mut rng);
        let fin = vec![x.clone(), w1.clone(), b1.clone(), w2.clone(),
                       b2.clone()];
        let pw = format!("pw_n{b}h14w14i32o64");
        let dw = format!("dw3_n{b}h14w14c64");
        // warmup both paths
        e.execute(&fused, &fin).unwrap();
        let m = e.execute(&pw, &[x.clone(), w1.clone(), b1.clone()])
            .unwrap()
            .remove(0);
        e.execute(&dw, &[m, w2.clone(), b2.clone()]).unwrap();
        let reps = 60;
        let t0 = Instant::now();
        for _ in 0..reps {
            e.execute(&fused, &fin).unwrap();
        }
        let tf = t0.elapsed().as_secs_f64() / reps as f64 * 1e3;
        let t0 = Instant::now();
        for _ in 0..reps {
            let m = e
                .execute(&pw, &[x.clone(), w1.clone(), b1.clone()])
                .unwrap()
                .remove(0);
            e.execute(&dw, &[m, w2.clone(), b2.clone()]).unwrap();
        }
        let tu = t0.elapsed().as_secs_f64() / reps as f64 * 1e3;
        println!(
            "pw+dw B={b}: fused {tf:.3} ms, unfused {tu:.3} ms \
             ({:.2}x)",
            tu / tf
        );
    }
}

fn main() {
    let budget: usize = std::env::var("AGO_BENCH_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000); // the paper's Fig. 13 budget
    println!("budget = {budget} evals per variant (paper: 2000)\n");
    for dev in [DeviceProfile::qsd810(), DeviceProfile::kirin990()] {
        for b in [1usize, 4] {
            println!("== {} batch {b} ==", dev.name);
            fig13_table(&dev, b, budget).print();
            println!();
        }
    }
    println!(
        "paper (Fig. 13): AGO-NI loses ~17% avg, AGO-NR ~27% avg; \
         AGO-NI can win on pw+pw at larger batch (Fig. 13(d))"
    );
    // The reformer's advantage depends on the budget-to-space ratio: our
    // cost-model evaluator saturates these 8-op spaces at 2000 evals, so
    // we also report the budget-starved regime where the paper's search
    // difficulty is reproduced (real-measurement tuners get far fewer
    // effective samples per op).
    println!("\n== budget-starved regime (120 evals) ==");
    for dev in [DeviceProfile::qsd810(), DeviceProfile::kirin990()] {
        println!("== {} batch 4 ==", dev.name);
        fig13_table(&dev, 4, 120).print();
        println!();
    }
    real_execution_section();
}

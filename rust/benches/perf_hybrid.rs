//! Hybrid backend-dispatch bench: the PR acceptance scenario, measured.
//!
//! Compiles the seed zoo at Small on both reference SoCs two ways — a
//! pure-tuned arm (`hybrid: false`) and a hybrid arm (`hybrid: true`,
//! Select racing the hand-library price against the tuned price per
//! class) — then replays the hybrid arm's handlib receipts through a
//! fresh compile to measure the FullTune budget the prune rule skips.
//!
//! Gates, every run (`--quick` only shrinks the budget):
//!   - per (model, device), hybrid total_latency <= pure-tuned (the two
//!     arms share the search trajectory bit for bit, so the comparison
//!     is exact — no tolerance needed) and strictly better somewhere
//!   - at least one class across the sweep dispatches to the hand
//!     library (else the arms are identical and the bench is vacuous)
//!   - adopting the handlib receipts skips search outright: the
//!     receipt-seeded recompile reports saved_evals > 0 and searches
//!     only the non-library classes
//!   - hybrid plan + db bytes are identical at 1 and 4 workers
//!
//! Writes `BENCH_hybrid.json` next to the other BENCH records.

use std::time::Instant;

use ago::coordinator::{
    compile_with_db, plan, CompileConfig, TuningDb, HANDLIB_VARIANT,
};
use ago::device::DeviceProfile;
use ago::models::{build, InputShape, ModelId};
use ago::util::json::{num, obj, s};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let budget = if quick { 300 } else { 900 };
    let devices = [DeviceProfile::kirin990(), DeviceProfile::qsd810()];
    let cfg = |dev: &DeviceProfile, hybrid: bool, workers: usize| {
        CompileConfig {
            budget,
            workers,
            hybrid,
            ..CompileConfig::new(dev.clone())
        }
    };

    // ---- two arms over the zoo at Small on both SoCs ----
    // every compile runs COLD (fresh db): with no seed to prune against,
    // the hybrid arm's searches are bit-identical to the tuned arm's and
    // the never-worse comparison below is exact, not statistical. The
    // hybrid arm's db entries are merged into one accumulator so the
    // prune scenario after the gates can replay its handlib receipts.
    let run_arm = |hybrid: bool| {
        let mut merged = TuningDb::new();
        let mut evals = 0usize;
        let mut handlib = 0usize;
        let mut lats = Vec::new();
        let t0 = Instant::now();
        for dev in &devices {
            for model in ModelId::all() {
                let g = build(model, InputShape::Small);
                let mut db = TuningDb::new();
                let m = compile_with_db(&g, &cfg(dev, hybrid, 0), &mut db);
                for e in db.entries() {
                    merged.record(e.clone());
                }
                evals += m.total_evals;
                handlib += m.handlib_classes;
                lats.push((model.name(), dev.name, m.total_latency));
            }
        }
        (merged, evals, handlib, lats, t0.elapsed().as_secs_f64())
    };
    let (_tdb, tuned_evals, tuned_handlib, tuned_lats, tuned_secs) =
        run_arm(false);
    let (hdb, hyb_evals, handlib_classes, hyb_lats, hyb_secs) = run_arm(true);
    assert_eq!(tuned_handlib, 0, "pure-tuned arm dispatched to the library");

    // ---- never-worse gates ----
    let mut strictly_better = 0usize;
    for ((name, dev, t), (_, _, h)) in tuned_lats.iter().zip(&hyb_lats) {
        assert!(
            h <= t,
            "{name}/{dev}: hybrid latency {h} worse than pure-tuned {t}"
        );
        if h < t {
            strictly_better += 1;
        }
        println!("  {name}/{dev}: tuned {t:.6}s, hybrid {h:.6}s");
    }
    assert!(
        handlib_classes > 0,
        "no class dispatched to the hand library: the arms are identical"
    );
    assert!(
        strictly_better > 0,
        "hybrid never strictly improved a plan despite {handlib_classes} \
         handlib classes"
    );
    println!(
        "hybrid: {handlib_classes} handlib class(es), strictly better on \
         {strictly_better}/{} sweeps",
        hyb_lats.len()
    );

    // ---- prune accounting: receipts skip FullTune outright ----
    // a handlib entry without a tuned sibling is the pruned-class marker;
    // seed a fresh db with only the receipts and recompile the sweep —
    // every previously-dispatched class is adopted without search and its
    // FullTune budget is reported saved
    let mut lib_only = TuningDb::new();
    for e in hdb.entries().filter(|e| e.variant == HANDLIB_VARIANT) {
        lib_only.record(e.clone());
    }
    let mut saved_evals = 0usize;
    let mut adopted = 0usize;
    for dev in &devices {
        for model in ModelId::all() {
            let g = build(model, InputShape::Small);
            let mut db = lib_only.clone();
            let m = compile_with_db(&g, &cfg(dev, true, 0), &mut db);
            saved_evals += m.saved_evals;
            adopted += m.handlib_classes;
            assert!(
                m.tuned_tasks + m.handlib_classes >= m.n_classes,
                "{}/{}: classes neither searched nor adopted",
                model.name(),
                dev.name
            );
        }
    }
    assert!(
        saved_evals > 0,
        "receipt-seeded recompile saved no FullTune evals \
         ({adopted} adopted classes)"
    );
    println!(
        "pruning: {adopted} adopted class(es) saved {saved_evals} FullTune \
         evals on the receipt-seeded sweep"
    );

    // ---- determinism: hybrid plan/db bytes at 1 vs 4 workers ----
    let g = build(ModelId::Sqn, InputShape::Small);
    let dev = &devices[0];
    let mk = |workers: usize| {
        let mut db = TuningDb::new();
        let m = compile_with_db(&g, &cfg(dev, true, workers), &mut db);
        (
            plan::to_json(&m, "sqn", dev.name).pretty(),
            db.to_json().pretty(),
        )
    };
    let (p1, d1) = mk(1);
    let (p4, d4) = mk(4);
    assert_eq!(p1, p4, "hybrid plan bytes depend on worker count");
    assert_eq!(d1, d4, "hybrid db bytes depend on worker count");
    println!("byte gates: worker independence OK");

    let record = obj(vec![
        ("bench", s("perf_hybrid")),
        ("quick", num(if quick { 1.0 } else { 0.0 })),
        ("models", s("all/small x kirin990,qsd810")),
        ("budget", num(budget as f64)),
        ("tuned_evals", num(tuned_evals as f64)),
        ("hybrid_evals", num(hyb_evals as f64)),
        ("handlib_classes", num(handlib_classes as f64)),
        ("strictly_better", num(strictly_better as f64)),
        ("adopted_classes", num(adopted as f64)),
        ("saved_evals", num(saved_evals as f64)),
        ("tuned_secs", num(tuned_secs)),
        ("hybrid_secs", num(hyb_secs)),
        (
            "latency_ratio_worst",
            num(tuned_lats
                .iter()
                .zip(&hyb_lats)
                .map(|((_, _, t), (_, _, h))| h / t)
                .fold(0.0f64, f64::max)),
        ),
    ]);
    std::fs::write("BENCH_hybrid.json", record.pretty())
        .expect("write BENCH_hybrid.json");
    println!("wrote BENCH_hybrid.json");
}

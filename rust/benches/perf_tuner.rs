//! L3 perf bench: tuner search throughput (schedule evaluations per
//! second, direct vs memoized evaluator), partitioner throughput, and
//! full-model compile wall time — the compile-time hot paths. Feeds
//! EXPERIMENTS.md §Perf and writes `BENCH_tuner.json` so the perf
//! trajectory is tracked PR-over-PR.

use std::time::Instant;

use ago::costmodel::{CostEvaluator, DirectEvaluator, MemoEvaluator};
use ago::device::DeviceProfile;
use ago::graph::{Graph, OpKind, Shape, Subgraph};
use ago::models::{build, InputShape, ModelId};
use ago::partition::{cluster, ClusterConfig};
use ago::tuner::schedule::SubgraphView;
use ago::tuner::search::{tune, tune_with_evaluator, SearchConfig};
use ago::util::json::{num, obj, s};

fn rep_subgraph() -> (Graph, SubgraphView) {
    // representative complicated subgraph: pw -> bias -> relu -> dw ->
    // bias -> relu -> pw -> bias (3 complex ops, 8 nodes)
    let mut g = Graph::new("perf");
    let s = Shape::nhwc(1, 28, 28, 32);
    let m = Shape::nhwc(1, 28, 28, 64);
    let i = g.add(OpKind::Pad, "in", s.clone(), 0, &[]);
    let pw = g.add(OpKind::Pointwise, "pw", m.clone(), 32, &[i]);
    let b1 = g.add(OpKind::BiasAdd, "b1", m.clone(), 0, &[pw]);
    let r1 = g.add(OpKind::ReLU, "r1", m.clone(), 0, &[b1]);
    let dw = g.add(OpKind::Depthwise { kh: 3, kw: 3, stride: 1 }, "dw",
                   m.clone(), 0, &[r1]);
    let b2 = g.add(OpKind::BiasAdd, "b2", m.clone(), 0, &[dw]);
    let r2 = g.add(OpKind::ReLU, "r2", m.clone(), 0, &[b2]);
    let pw2 = g.add(OpKind::Pointwise, "pw2", s, 64, &[r2]);
    let nodes = vec![i, pw, b1, r1, dw, b2, r2, pw2];
    let view = SubgraphView::new(&g, &Subgraph { id: 0, nodes });
    (g, view)
}

fn main() {
    let dev = DeviceProfile::kirin990();
    let (g, view) = rep_subgraph();

    // search throughput: run a large fixed budget, time it
    let budget = 50_000;
    let cfg = SearchConfig {
        budget,
        stabilize_window: budget, // never early-stop: measure raw rate
        seed: 7,
        ..Default::default()
    };
    let t0 = Instant::now();
    let r = tune(&g, &view, &dev, &cfg, None);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "tuner throughput: {:.0} evals/s ({} evals in {:.2}s, best {:.4} ms)",
        r.evals as f64 / dt,
        r.evals,
        dt,
        r.best_latency * 1e3
    );

    // partitioner throughput on the biggest graph (MVT, 382 ops)
    let mvt = build(ModelId::Mvt, InputShape::Large);
    let cfg = ClusterConfig::adaptive(&mvt);
    let t0 = Instant::now();
    let reps = 50;
    for _ in 0..reps {
        let p = cluster(&mvt, cfg);
        std::hint::black_box(p);
    }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "CLUSTER on MVT ({} ops): {:.2} ms/partition",
        mvt.len(),
        dt * 1e3
    );

    // direct vs memoized evaluator at the acceptance budget: 4000 evals
    // on MBN's heaviest subgraph, stabilization disabled so both paths
    // spend the identical evaluation count
    let mbn = build(ModelId::Mbn, InputShape::Middle);
    let p = cluster(&mbn, ClusterConfig::adaptive(&mbn));
    let views = SubgraphView::all(&mbn, &p);
    let heavy = views
        .iter()
        .filter(|v| !v.is_empty())
        .max_by_key(|v| (v.complex.len(), v.order.len()))
        .expect("mbn has subgraphs");
    let budget = 4000;
    let cfg = SearchConfig {
        budget,
        stabilize_window: budget,
        seed: 7,
        ..Default::default()
    };
    let t0 = Instant::now();
    let mut direct = DirectEvaluator::new(&mbn, &dev);
    let rd = tune_with_evaluator(&mbn, heavy, &cfg, None, &mut direct);
    let dt_direct = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let mut memo = MemoEvaluator::new(&mbn, &dev);
    let rm = tune_with_evaluator(&mbn, heavy, &cfg, None, &mut memo);
    let dt_memo = t0.elapsed().as_secs_f64();
    assert_eq!(
        rd.best_latency, rm.best_latency,
        "memoization changed the search result"
    );
    let eps_direct = rd.evals as f64 / dt_direct;
    let eps_memo = rm.evals as f64 / dt_memo;
    let hit_rate = memo.stats().hit_rate();
    println!(
        "MBN heavy subgraph @ {budget} evals: direct {eps_direct:.0} \
         evals/s, memoized {eps_memo:.0} evals/s ({:.2}x, hit-rate \
         {:.1}%)",
        eps_memo / eps_direct,
        hit_rate * 100.0
    );

    // full-model compile wall time at the paper budget
    let t0 = Instant::now();
    let out = ago::coordinator::compile(
        &build(ModelId::Mbn, InputShape::Large),
        &ago::coordinator::CompileConfig {
            budget: 20_000,
            ..ago::coordinator::CompileConfig::new(dev)
        },
    );
    let compile_secs = t0.elapsed().as_secs_f64();
    println!(
        "MBN/large compile @ 20k budget: {compile_secs:.2}s wall \
         ({} evals, {:.0} evals/s, hit-rate {:.1}%)",
        out.total_evals,
        out.evals_per_sec,
        out.cache_hit_rate * 100.0
    );

    // perf trajectory record
    let record = obj(vec![
        ("bench", s("perf_tuner")),
        ("model", s("mbn")),
        ("budget", num(budget as f64)),
        ("evals_per_sec_direct", num(eps_direct)),
        ("evals_per_sec_memo", num(eps_memo)),
        ("memo_speedup", num(eps_memo / eps_direct)),
        ("cache_hit_rate", num(hit_rate)),
        ("compile_20k_secs", num(compile_secs)),
        ("compile_20k_evals_per_sec", num(out.evals_per_sec)),
        ("compile_20k_cache_hit_rate", num(out.cache_hit_rate)),
    ]);
    std::fs::write("BENCH_tuner.json", record.pretty())
        .expect("write BENCH_tuner.json");
    println!("wrote BENCH_tuner.json");
}

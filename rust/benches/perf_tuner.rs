//! L3 perf bench: tuner search throughput (schedule evaluations per
//! second, direct vs memoized evaluator), the batched-generational
//! worker-scaling curve (1/2/4/8 workers; gates >=3x evals/sec at 8
//! workers on >=8-core hosts AND 1-worker batched >= 0.7x the
//! steady-state lambda=1 loop), partitioner throughput, full-model
//! compile wall time, and the TuningDb cold-vs-warm compile comparison —
//! the compile-time hot paths. Feeds EXPERIMENTS.md §Perf and writes
//! `BENCH_tuner.json` so the perf trajectory is tracked PR-over-PR.
//!
//! `--quick` shrinks every budget ~10x for the CI smoke run: the numbers
//! are noisier but the cold-vs-warm comparison and the dedup/hit-rate
//! assertions still hold, so every CI run produces a `BENCH_tuner.json`
//! artifact instead of only local runs.

use std::time::Instant;

use ago::coordinator::{compile_with_db, CompileConfig, TuningDb};
use ago::costmodel::{
    CostEvaluator, DirectEvaluator, MemoCache, MemoEvaluator,
    PricingContext,
};
use ago::device::DeviceProfile;
use ago::graph::{Graph, OpKind, Shape, Subgraph};
use ago::models::{build, InputShape, ModelId};
use ago::partition::{cluster, ClusterConfig};
use ago::tuner::schedule::SubgraphView;
use ago::tuner::search::{tune, tune_parallel, tune_with_evaluator, SearchConfig};
use ago::util::json::{num, obj, s};
use ago::util::ThreadPool;

fn rep_subgraph() -> (Graph, SubgraphView) {
    // representative complicated subgraph: pw -> bias -> relu -> dw ->
    // bias -> relu -> pw -> bias (3 complex ops, 8 nodes)
    let mut g = Graph::new("perf");
    let s = Shape::nhwc(1, 28, 28, 32);
    let m = Shape::nhwc(1, 28, 28, 64);
    let i = g.add(OpKind::Pad, "in", s.clone(), 0, &[]);
    let pw = g.add(OpKind::Pointwise, "pw", m.clone(), 32, &[i]);
    let b1 = g.add(OpKind::BiasAdd, "b1", m.clone(), 0, &[pw]);
    let r1 = g.add(OpKind::ReLU, "r1", m.clone(), 0, &[b1]);
    let dw = g.add(OpKind::Depthwise { kh: 3, kw: 3, stride: 1 }, "dw",
                   m.clone(), 0, &[r1]);
    let b2 = g.add(OpKind::BiasAdd, "b2", m.clone(), 0, &[dw]);
    let r2 = g.add(OpKind::ReLU, "r2", m.clone(), 0, &[b2]);
    let pw2 = g.add(OpKind::Pointwise, "pw2", s, 64, &[r2]);
    let nodes = vec![i, pw, b1, r1, dw, b2, r2, pw2];
    let view = SubgraphView::new(&g, &Subgraph { id: 0, nodes });
    (g, view)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let dev = DeviceProfile::kirin990();
    let (g, view) = rep_subgraph();

    // search throughput: run a large fixed budget, time it
    let budget = if quick { 5_000 } else { 50_000 };
    let cfg = SearchConfig {
        budget,
        stabilize_window: budget, // never early-stop: measure raw rate
        seed: 7,
        ..Default::default()
    };
    let t0 = Instant::now();
    let r = tune(&g, &view, &dev, &cfg, None);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "tuner throughput: {:.0} evals/s ({} evals in {:.2}s, best {:.4} ms)",
        r.evals as f64 / dt,
        r.evals,
        dt,
        r.best_latency * 1e3
    );

    // partitioner throughput on the biggest graph (MVT, 382 ops)
    let mvt = build(ModelId::Mvt, InputShape::Large);
    let cfg = ClusterConfig::adaptive(&mvt);
    let t0 = Instant::now();
    let reps = if quick { 5 } else { 50 };
    for _ in 0..reps {
        let p = cluster(&mvt, cfg);
        std::hint::black_box(p);
    }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "CLUSTER on MVT ({} ops): {:.2} ms/partition",
        mvt.len(),
        dt * 1e3
    );

    // direct vs memoized evaluator at the acceptance budget: 4000 evals
    // on MBN's heaviest subgraph, stabilization disabled so both paths
    // spend the identical evaluation count
    let mbn = build(ModelId::Mbn, InputShape::Middle);
    let p = cluster(&mbn, ClusterConfig::adaptive(&mbn));
    let views = SubgraphView::all(&mbn, &p);
    let heavy = views
        .iter()
        .filter(|v| !v.is_empty())
        .max_by_key(|v| (v.complex.len(), v.order.len()))
        .expect("mbn has subgraphs");
    let budget = if quick { 600 } else { 4000 };
    let cfg = SearchConfig {
        budget,
        stabilize_window: budget,
        seed: 7,
        ..Default::default()
    };
    let t0 = Instant::now();
    let mut direct = DirectEvaluator::new(&mbn, &dev);
    let rd = tune_with_evaluator(&mbn, heavy, &cfg, None, &mut direct);
    let dt_direct = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let mut memo = MemoEvaluator::new(&mbn, &dev);
    let rm = tune_with_evaluator(&mbn, heavy, &cfg, None, &mut memo);
    let dt_memo = t0.elapsed().as_secs_f64();
    assert_eq!(
        rd.best_latency, rm.best_latency,
        "memoization changed the search result"
    );
    let eps_direct = rd.evals as f64 / dt_direct;
    let eps_memo = rm.evals as f64 / dt_memo;
    let hit_rate = memo.stats().hit_rate();
    println!(
        "MBN heavy subgraph @ {budget} evals: direct {eps_direct:.0} \
         evals/s, memoized {eps_memo:.0} evals/s ({:.2}x, hit-rate \
         {:.1}%)",
        eps_memo / eps_direct,
        hit_rate * 100.0
    );

    // --- worker-scaling curve: the batched-generational engine -------
    // Same heavy MBN subgraph, stabilization disabled, a large lambda so
    // each generation amortizes fan-out overhead. The candidate stream
    // is drawn on the driver, so every worker count must return the SAME
    // bits; only evals/sec moves.
    let scale_budget = if quick { 6_000 } else { 40_000 };
    let scfg = SearchConfig {
        budget: scale_budget,
        stabilize_window: scale_budget, // never early-stop: raw rate
        lambda: 256,
        seed: 7,
        ..Default::default()
    };
    // steady-state baseline: lambda = 1 IS the classic one-candidate
    // loop (draw, price, reduce) — the pre-batching reference the
    // 1-worker gate below protects
    let steady_cfg = SearchConfig { lambda: 1, ..scfg.clone() };
    let t0 = Instant::now();
    let mut steady_eval = MemoEvaluator::new(&mbn, &dev);
    let rs = tune_with_evaluator(&mbn, heavy, &steady_cfg, None,
                                 &mut steady_eval);
    let eps_steady = rs.evals as f64 / t0.elapsed().as_secs_f64();
    let ctx = PricingContext::new(&mbn, &dev);
    let mut eps_workers: Vec<(usize, f64)> = Vec::new();
    let mut ref_result: Option<(f64, usize)> = None;
    for workers in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(workers);
        let mut cache = MemoCache::new();
        let t0 = Instant::now();
        let r = tune_parallel(&mbn, heavy, &scfg, None, &ctx, &mut cache,
                              &pool);
        let dt = t0.elapsed().as_secs_f64();
        if let Some((lat, evals)) = ref_result {
            assert_eq!(
                r.best_latency, lat,
                "worker count changed the search result"
            );
            assert_eq!(r.evals, evals);
        } else {
            ref_result = Some((r.best_latency, r.evals));
        }
        eps_workers.push((workers, r.evals as f64 / dt));
    }
    let eps_w = |w: usize| {
        eps_workers.iter().find(|&&(n, _)| n == w).unwrap().1
    };
    let scaling = eps_w(8) / eps_w(1);
    println!(
        "worker scaling @ lambda 256, {scale_budget} evals: steady(l=1) \
         {eps_steady:.0}/s, batched 1w {:.0}/s, 2w {:.0}/s, 4w {:.0}/s, \
         8w {:.0}/s ({scaling:.2}x, bit-identical results)",
        eps_w(1),
        eps_w(2),
        eps_w(4),
        eps_w(8),
    );
    // gate 1: batching must not tax the serial case — 1-worker batched
    // throughput stays within noise of the steady-state loop (same
    // memoization, same per-candidate work; only loop structure differs)
    assert!(
        eps_w(1) >= 0.7 * eps_steady,
        "1-worker batched search regressed below steady-state: \
         {:.0}/s vs {eps_steady:.0}/s",
        eps_w(1)
    );
    // gate 2: the point of the exercise — >=3x evals/sec at 8 workers.
    // 3x needs >=8 real cores, so the full gate is conditioned on them;
    // on 4-7 cores demand only that parallelism measurably helps (a
    // single noisy timing sample on an oversubscribed shared runner
    // must not fail the bench), and below that report without gating.
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores >= 8 {
        assert!(
            scaling >= 3.0,
            "worker scaling {scaling:.2}x < 3x on {cores} cores"
        );
    } else if cores >= 4 {
        assert!(
            scaling >= 1.3,
            "worker scaling {scaling:.2}x: parallel pricing does not \
             help at all on {cores} cores"
        );
    } else {
        eprintln!(
            "note: {cores} cores — worker-scaling gate skipped \
             (measured {scaling:.2}x; recorded in BENCH_tuner.json)"
        );
    }

    // full-model compile wall time (paper budget; ~10x smaller in
    // --quick so the JSON record names the budget explicitly instead of
    // baking "20k" into a key that would silently mean two things)
    let full_budget = if quick { 2_000 } else { 20_000 };
    let t0 = Instant::now();
    let out = ago::coordinator::compile(
        &build(ModelId::Mbn, InputShape::Large),
        &ago::coordinator::CompileConfig {
            budget: full_budget,
            ..ago::coordinator::CompileConfig::new(dev.clone())
        },
    );
    let compile_secs = t0.elapsed().as_secs_f64();
    println!(
        "MBN/large compile @ {full_budget} budget: {compile_secs:.2}s wall \
         ({} evals, {:.0} evals/s, hit-rate {:.1}%, {} classes / {} \
         subgraphs)",
        out.total_evals,
        out.evals_per_sec,
        out.cache_hit_rate * 100.0,
        out.n_classes,
        out.partition.n_groups,
    );

    // cold-vs-warm compile through the TuningDb (the acceptance
    // scenario): first compile dedups structurally identical subgraphs
    // and fills the db; the second compile of the same model must hit
    // ≥ 90% of its classes and skip every search
    let small = build(ModelId::Mbn, InputShape::Small);
    let ccfg = CompileConfig {
        budget: if quick { 800 } else { 4000 },
        workers: 0,
        ..CompileConfig::new(dev)
    };
    let mut db = TuningDb::new();
    let t0 = Instant::now();
    let cold = compile_with_db(&small, &ccfg, &mut db);
    let cold_secs = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let warm = compile_with_db(&small, &ccfg, &mut db);
    let warm_secs = t0.elapsed().as_secs_f64();
    assert!(
        cold.tuned_tasks < cold.partition.n_groups,
        "dedup must tune fewer tasks ({}) than subgraphs ({})",
        cold.tuned_tasks,
        cold.partition.n_groups
    );
    assert!(
        warm.class_hit_rate >= 0.9,
        "warm compile hit-rate {} < 0.9",
        warm.class_hit_rate
    );
    assert_eq!(
        warm.total_latency, cold.total_latency,
        "warm compile must adopt the cold compile's schedules"
    );
    println!(
        "MBN/small cold-vs-warm: cold {:.2}s ({} classes / {} subgraphs, \
         {} tuned) -> warm {:.3}s ({:.0}% hit-rate, {} evals), {:.1}x \
         compile speedup",
        cold_secs,
        cold.n_classes,
        cold.partition.n_groups,
        cold.tuned_tasks,
        warm_secs,
        warm.class_hit_rate * 100.0,
        warm.total_evals,
        cold_secs / warm_secs.max(1e-9),
    );

    // perf trajectory record
    let record = obj(vec![
        ("bench", s("perf_tuner")),
        ("model", s("mbn")),
        ("quick", num(if quick { 1.0 } else { 0.0 })),
        ("budget", num(budget as f64)),
        ("evals_per_sec_direct", num(eps_direct)),
        ("evals_per_sec_memo", num(eps_memo)),
        ("memo_speedup", num(eps_memo / eps_direct)),
        ("cache_hit_rate", num(hit_rate)),
        // worker-scaling curve of the batched-generational engine (the
        // CI gate: w8/w1 >= 3x on >=8-core hosts, and w1 must not fall
        // below the steady-state lambda=1 baseline)
        ("scale_budget", num(scale_budget as f64)),
        ("evals_per_sec_steady", num(eps_steady)),
        ("evals_per_sec_w1", num(eps_w(1))),
        ("evals_per_sec_w2", num(eps_w(2))),
        ("evals_per_sec_w4", num(eps_w(4))),
        ("evals_per_sec_w8", num(eps_w(8))),
        ("worker_scaling_8w", num(scaling)),
        // renamed from compile_20k_*: the budget varies with --quick, so
        // the record names it instead of a key silently meaning 2k or 20k
        ("compile_full_budget", num(full_budget as f64)),
        ("compile_full_secs", num(compile_secs)),
        ("compile_full_evals_per_sec", num(out.evals_per_sec)),
        ("compile_full_cache_hit_rate", num(out.cache_hit_rate)),
        ("n_subgraphs", num(cold.partition.n_groups as f64)),
        ("n_classes", num(cold.n_classes as f64)),
        ("tuned_tasks_cold", num(cold.tuned_tasks as f64)),
        ("compile_cold_secs", num(cold_secs)),
        ("compile_warm_secs", num(warm_secs)),
        ("warm_class_hit_rate", num(warm.class_hit_rate)),
        ("warm_speedup", num(cold_secs / warm_secs.max(1e-9))),
    ]);
    std::fs::write("BENCH_tuner.json", record.pretty())
        .expect("write BENCH_tuner.json");
    println!("wrote BENCH_tuner.json");
}

//! L3 perf bench: tuner search throughput (schedule evaluations per
//! second) and partitioner throughput — the compile-time hot paths.
//! Feeds EXPERIMENTS.md §Perf.

use std::time::Instant;

use ago::device::DeviceProfile;
use ago::graph::{Graph, OpKind, Shape, Subgraph};
use ago::models::{build, InputShape, ModelId};
use ago::partition::{cluster, ClusterConfig};
use ago::tuner::schedule::SubgraphView;
use ago::tuner::search::{tune, SearchConfig};

fn rep_subgraph() -> (Graph, SubgraphView) {
    // representative complicated subgraph: pw -> bias -> relu -> dw ->
    // bias -> relu -> pw -> bias (3 complex ops, 8 nodes)
    let mut g = Graph::new("perf");
    let s = Shape::nhwc(1, 28, 28, 32);
    let m = Shape::nhwc(1, 28, 28, 64);
    let i = g.add(OpKind::Pad, "in", s.clone(), 0, &[]);
    let pw = g.add(OpKind::Pointwise, "pw", m.clone(), 32, &[i]);
    let b1 = g.add(OpKind::BiasAdd, "b1", m.clone(), 0, &[pw]);
    let r1 = g.add(OpKind::ReLU, "r1", m.clone(), 0, &[b1]);
    let dw = g.add(OpKind::Depthwise { kh: 3, kw: 3, stride: 1 }, "dw",
                   m.clone(), 0, &[r1]);
    let b2 = g.add(OpKind::BiasAdd, "b2", m.clone(), 0, &[dw]);
    let r2 = g.add(OpKind::ReLU, "r2", m.clone(), 0, &[b2]);
    let pw2 = g.add(OpKind::Pointwise, "pw2", s, 64, &[r2]);
    let nodes = vec![i, pw, b1, r1, dw, b2, r2, pw2];
    let view = SubgraphView::new(&g, &Subgraph { id: 0, nodes });
    (g, view)
}

fn main() {
    let dev = DeviceProfile::kirin990();
    let (g, view) = rep_subgraph();

    // search throughput: run a large fixed budget, time it
    let budget = 50_000;
    let cfg = SearchConfig {
        budget,
        stabilize_window: budget, // never early-stop: measure raw rate
        seed: 7,
        ..Default::default()
    };
    let t0 = Instant::now();
    let r = tune(&g, &view, &dev, &cfg, None);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "tuner throughput: {:.0} evals/s ({} evals in {:.2}s, best {:.4} ms)",
        r.evals as f64 / dt,
        r.evals,
        dt,
        r.best_latency * 1e3
    );

    // partitioner throughput on the biggest graph (MVT, 382 ops)
    let mvt = build(ModelId::Mvt, InputShape::Large);
    let cfg = ClusterConfig::adaptive(&mvt);
    let t0 = Instant::now();
    let reps = 50;
    for _ in 0..reps {
        let p = cluster(&mvt, cfg);
        std::hint::black_box(p);
    }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "CLUSTER on MVT ({} ops): {:.2} ms/partition",
        mvt.len(),
        dt * 1e3
    );

    // full-model compile wall time at the paper budget
    let t0 = Instant::now();
    let out = ago::coordinator::compile(
        &build(ModelId::Mbn, InputShape::Large),
        &ago::coordinator::CompileConfig {
            budget: 20_000,
            ..ago::coordinator::CompileConfig::new(dev)
        },
    );
    println!(
        "MBN/large compile @ 20k budget: {:.2}s wall ({} evals)",
        t0.elapsed().as_secs_f64(),
        out.total_evals
    );
}

//! Learned cost-model bench: the PR acceptance scenario, measured.
//!
//! Builds a transfer corpus by compiling the seed zoo at Small AND
//! Large on kirin990 (so the Middle-shape arms below are an
//! INTERPOLATION task for the model, not an extrapolation), then
//! compiles the zoo at Middle two ways against clones of that corpus:
//! a baseline arm (`learned: false` — every Middle class tunes cold,
//! its fingerprints are new) and a learned arm (`learned: true` — the
//! corpus-fit model warm-seeds each class from its nearest tuned
//! relative in feature space, gated never-worse by the probe margin).
//!
//! Gates, every run (`--quick` only shrinks the budget):
//!   - the learned arm spends <= 75% of the baseline arm's schedule
//!     evaluations (the ISSUE's ">= 25% fewer evals" acceptance)
//!   - per model, learned total_latency <= baseline * 1.01 (1% is the
//!     search's own improvement resolution — plans never worse)
//!   - at least one class actually took a learned seed (else the eval
//!     gate would be vacuously comparing identical cold runs)
//!   - `--learned` against an EMPTY db is byte-identical to the
//!     unlearned compile, plan and db both (the flag is inert without
//!     a corpus), at K = 1 and K = 4
//!   - learned plan + db bytes are identical at 1 and 4 workers
//!
//! Writes `BENCH_learned.json` next to the other BENCH records.

use std::time::Instant;

use ago::coordinator::{compile_with_db, plan, CompileConfig, TuningDb};
use ago::device::DeviceProfile;
use ago::models::{build, InputShape, ModelId};
use ago::util::json::{num, obj, s};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let budget = if quick { 400 } else { 1000 };
    let dev = DeviceProfile::kirin990();
    let cfg = |learned: bool, workers: usize| CompileConfig {
        budget,
        workers,
        learned,
        ..CompileConfig::new(dev.clone())
    };

    // ---- corpus: zoo at Small + Large (the model's training set) ----
    let t0 = Instant::now();
    let mut corpus = TuningDb::new();
    for shape in [InputShape::Small, InputShape::Large] {
        for model in ModelId::all() {
            let g = build(model, shape);
            compile_with_db(&g, &cfg(false, 0), &mut corpus);
        }
    }
    println!(
        "corpus: {} entries from {} compiles in {:.2}s",
        corpus.len(),
        2 * ModelId::all().len(),
        t0.elapsed().as_secs_f64()
    );

    // ---- two arms over the zoo at Middle, against corpus clones ----
    let run_arm = |learned: bool| {
        let mut db = corpus.clone();
        let mut evals = 0usize;
        let mut seeds = 0usize;
        let mut lats = Vec::new();
        let t0 = Instant::now();
        for model in ModelId::all() {
            let g = build(model, InputShape::Middle);
            let m = compile_with_db(&g, &cfg(learned, 0), &mut db);
            evals += m.total_evals;
            seeds += m.learned_seeds;
            lats.push((model.name(), m.total_latency));
        }
        (evals, seeds, lats, t0.elapsed().as_secs_f64())
    };
    let (base_evals, base_seeds, base_lats, base_secs) = run_arm(false);
    let (lrn_evals, lrn_seeds, lrn_lats, lrn_secs) = run_arm(true);
    assert_eq!(base_seeds, 0, "unlearned arm took learned seeds");
    println!(
        "evals: baseline {base_evals}, learned {lrn_evals} \
         ({:.0}% — {lrn_seeds} NN-seeded classes)",
        100.0 * lrn_evals as f64 / base_evals.max(1) as f64
    );

    // ---- acceptance gates ----
    assert!(
        lrn_seeds > 0,
        "no class took a learned seed: the arms are identical cold runs"
    );
    assert!(
        lrn_evals as f64 <= 0.75 * base_evals as f64,
        "learned arm spent {lrn_evals} evals, needs <= 75% of baseline \
         {base_evals}"
    );
    for ((name, b), (_, l)) in base_lats.iter().zip(&lrn_lats) {
        assert!(
            *l <= b * 1.01,
            "{name}: learned latency {l} worse than baseline {b}"
        );
        println!("  {name}: baseline {b:.6}s, learned {l:.6}s");
    }

    // ---- inertness: --learned with an empty db is byte-identical ----
    for k in [1usize, 4] {
        let g = build(ModelId::Mbn, InputShape::Small);
        let mk = |learned: bool| {
            let c = CompileConfig {
                partition_candidates: k,
                ..cfg(learned, 2)
            };
            let mut db = TuningDb::new();
            let m = compile_with_db(&g, &c, &mut db);
            assert_eq!(m.learned_seeds, 0, "seeded with no corpus at K={k}");
            (
                plan::to_json(&m, "mbn", dev.name).pretty(),
                db.to_json().pretty(),
            )
        };
        let (p0, d0) = mk(false);
        let (p1, d1) = mk(true);
        assert_eq!(p0, p1, "empty-db --learned changed plan bytes at K={k}");
        assert_eq!(d0, d1, "empty-db --learned changed db bytes at K={k}");
    }

    // ---- determinism: learned plan/db bytes at 1 vs 4 workers ----
    let g = build(ModelId::Mbn, InputShape::Middle);
    let mk = |workers: usize| {
        let mut db = corpus.clone();
        let m = compile_with_db(&g, &cfg(true, workers), &mut db);
        (
            plan::to_json(&m, "mbn", dev.name).pretty(),
            db.to_json().pretty(),
        )
    };
    let (p1, d1) = mk(1);
    let (p4, d4) = mk(4);
    assert_eq!(p1, p4, "learned plan bytes depend on worker count");
    assert_eq!(d1, d4, "learned db bytes depend on worker count");
    println!("byte gates: empty-db inertness + worker independence OK");

    let record = obj(vec![
        ("bench", s("perf_learned")),
        ("quick", num(if quick { 1.0 } else { 0.0 })),
        ("models", s("all/middle")),
        ("budget", num(budget as f64)),
        ("corpus_entries", num(corpus.len() as f64)),
        ("baseline_evals", num(base_evals as f64)),
        ("learned_evals", num(lrn_evals as f64)),
        (
            "eval_ratio",
            num(lrn_evals as f64 / base_evals.max(1) as f64),
        ),
        ("learned_seeds", num(lrn_seeds as f64)),
        ("baseline_secs", num(base_secs)),
        ("learned_secs", num(lrn_secs)),
        (
            "latency_ratio_worst",
            num(base_lats
                .iter()
                .zip(&lrn_lats)
                .map(|((_, b), (_, l))| l / b)
                .fold(0.0f64, f64::max)),
        ),
    ]);
    std::fs::write("BENCH_learned.json", record.pretty())
        .expect("write BENCH_learned.json");
    println!("wrote BENCH_learned.json");
}

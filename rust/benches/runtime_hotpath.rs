//! Runtime hot-path micro-benchmarks (the L3 perf deliverable):
//! per-execute dispatch overhead, literal conversion cost, executable
//! cache behavior, and chain throughput. Feeds EXPERIMENTS.md §Perf.

use std::time::Instant;

use ago::runtime::{Engine, TensorData};
use ago::util::benchkit::{quick, Table};
use ago::util::Rng;

fn main() {
    let dir = std::env::var("AGO_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let mut e = Engine::new(&dir).expect("run `make artifacts` first");
    let mut rng = Rng::new(1);

    // smallest artifact = dispatch floor
    let add_in = [
        TensorData::random(&[1, 7, 7, 32], &mut rng),
        TensorData::random(&[1, 7, 7, 32], &mut rng),
    ];
    e.execute("add_n1h7w7c32", &add_in).unwrap(); // compile+warm

    let mut t = Table::new(&["metric", "p50", "mean"]);
    let r = quick("add dispatch", || {
        e.execute("add_n1h7w7c32", &add_in).unwrap();
    });
    t.row(vec![
        "tiny-kernel execute (dispatch floor)".into(),
        format!("{:.1} us", r.p50_ns / 1e3),
        format!("{:.1} us", r.mean_ns / 1e3),
    ]);

    // medium artifact
    let mut e2 = Engine::new(&dir).unwrap();
    let pw_in = [
        TensorData::random(&[1, 28, 28, 16], &mut rng),
        TensorData::random(&[16, 32], &mut rng),
        TensorData::random(&[32], &mut rng),
    ];
    e2.execute("pw_n1h28w28i16o32", &pw_in).unwrap();
    let r = quick("pw execute", || {
        e2.execute("pw_n1h28w28i16o32", &pw_in).unwrap();
    });
    t.row(vec![
        "pw 28x28x16->32 execute".into(),
        format!("{:.1} us", r.p50_ns / 1e3),
        format!("{:.1} us", r.mean_ns / 1e3),
    ]);

    // literal conversion cost (host -> PJRT buffer path dominates small
    // kernels; measured via zero-flop add on a bigger tensor)
    let big = [
        TensorData::random(&[1, 28, 28, 16], &mut rng),
        TensorData::random(&[1, 28, 28, 16], &mut rng),
    ];
    let mut e3 = Engine::new(&dir).unwrap();
    e3.execute("add_n1h28w28c16", &big).unwrap();
    let r = quick("add 28x28x16", || {
        e3.execute("add_n1h28w28c16", &big).unwrap();
    });
    t.row(vec![
        "add 28x28x16 (conversion-bound)".into(),
        format!("{:.1} us", r.p50_ns / 1e3),
        format!("{:.1} us", r.mean_ns / 1e3),
    ]);
    t.print();

    // cold-compile cost amortization
    let t0 = Instant::now();
    let mut e4 = Engine::new(&dir).unwrap();
    e4.prepare("mbnblk_fused_n1h28w28c16e2").unwrap();
    println!(
        "\ncold compile of mbn block artifact: {:.1} ms (cached \
         thereafter; {} executables resident)",
        t0.elapsed().as_secs_f64() * 1e3,
        e4.compiled_count()
    );

    // chain throughput
    let names: Vec<String> = vec![
        "pw_n1h14w14i24o48".into(),
        "dw3_n1h14w14c48".into(),
        "pw_n1h14w14i48o24".into(),
    ];
    let x = TensorData::random(&[1, 14, 14, 24], &mut rng);
    e.run_chain(&names, x.clone(), 1).unwrap(); // warm
    let reps = 200;
    let t0 = Instant::now();
    for _ in 0..reps {
        e.run_chain(&names, x.clone(), 1).unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "3-op chain: {:.3} ms/req, {:.0} req/s",
        dt / reps as f64 * 1e3,
        reps as f64 / dt
    );
}

//! Fleet compile-farm bench: the PR acceptance scenario, measured.
//!
//! Compiles the six-model seed zoo three ways — serial per-model
//! compiles against one shared TuningDb (the pre-fleet baseline),
//! `fleet_compile` at 1 worker, and `fleet_compile` at 8 workers — and
//! gates on every run:
//!   - merged-db AND plan bytes identical between the 1- and 8-worker
//!     fleets (parallelism is a wall-clock knob only)
//!   - fleet stats identical across worker counts
//!   - 8-worker fleet wall-clock vs the serial baseline, gated
//!     proportionally to the host: >= 2.0x on 8+ cores, >= 1.3x on
//!     4-7, report-only below (CI runners vary; the contract is "the
//!     farm uses the cores it is given")
//!   - a warm rerun over the populated db hits >= 90% of classes and
//!     leaves the merged-db bytes unchanged
//!   - the sharded store round-trips the merged db byte-exactly at
//!     K=4 and K=16
//!
//! Writes `BENCH_fleet.json` next to the other BENCH records. `--quick`
//! shrinks the budget for the CI smoke run; every gate still runs.
//!
//! NOTE: the serial baseline and the fleet produce different db BYTES
//! by design — a serial compile warm-seeds model N's searches from
//! models 1..N-1's finished entries, while the fleet's ledger resolves
//! all seeds per device wave before any search records. Both are
//! deterministic; they are different (equally valid) tuning outcomes.
//! The byte-identity contract is fleet-vs-fleet.

use std::time::Instant;

use ago::coordinator::{
    compile_with_db, fleet_compile, plan, CompileConfig, FleetJob,
    ShardStore, TuningDb,
};
use ago::device::DeviceProfile;
use ago::models::{build, InputShape, ModelId};
use ago::util::json::{num, obj, s};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let budget = if quick { 300 } else { 1200 };
    let dev = DeviceProfile::kirin990();
    let jobs: Vec<FleetJob> = ModelId::all()
        .into_iter()
        .map(|model| FleetJob {
            model,
            shape: InputShape::Small,
            device: dev.clone(),
        })
        .collect();
    let cfg = |workers: usize| CompileConfig {
        budget,
        workers,
        ..CompileConfig::new(dev.clone())
    };

    // ---- serial baseline: one model at a time, shared db, 1 worker ----
    let t0 = Instant::now();
    let mut serial_db = TuningDb::new();
    for job in &jobs {
        let g = build(job.model, job.shape);
        compile_with_db(&g, &cfg(1), &mut serial_db);
    }
    let serial_secs = t0.elapsed().as_secs_f64();
    println!(
        "serial baseline: {} models in {serial_secs:.2}s \
         ({} db entries)",
        jobs.len(),
        serial_db.len()
    );

    // ---- fleet at 1 worker (byte-identity reference) ----
    let t0 = Instant::now();
    let mut db1 = TuningDb::new();
    let out1 = fleet_compile(&jobs, &cfg(1), &mut db1);
    let fleet1_secs = t0.elapsed().as_secs_f64();

    // ---- fleet at 8 workers (the measured configuration) ----
    let t0 = Instant::now();
    let mut db8 = TuningDb::new();
    let out8 = fleet_compile(&jobs, &cfg(8), &mut db8);
    let fleet8_secs = t0.elapsed().as_secs_f64();
    println!(
        "fleet: w1 {fleet1_secs:.2}s, w8 {fleet8_secs:.2}s \
         ({} classes -> {} ledger tasks, hit rate {:.0}%)",
        out8.stats.classes,
        out8.stats.ledger_tasks,
        out8.stats.hit_rate * 100.0
    );

    // ---- byte-identity gates ----
    let bytes1 = db1.to_json().pretty();
    let bytes8 = db8.to_json().pretty();
    assert_eq!(bytes1, bytes8, "merged db bytes depend on worker count");
    for ((j, a), b) in out1.jobs.iter().zip(&out1.models).zip(&out8.models)
    {
        assert_eq!(
            plan::to_json(a, j.model.name(), j.device.name).pretty(),
            plan::to_json(b, j.model.name(), j.device.name).pretty(),
            "{}: plan bytes depend on worker count",
            j.label()
        );
    }
    assert_eq!(
        out1.stats.to_json().pretty(),
        out8.stats.to_json().pretty(),
        "fleet stats depend on worker count"
    );

    // ---- sharded-store round trip at two shard counts ----
    for k in [4usize, 16] {
        let dir = std::env::temp_dir().join(format!("ago_bench_fleet_k{k}"));
        std::fs::remove_dir_all(&dir).ok();
        let store = ShardStore::new(&dir, k);
        store.save(&db8).expect("shard save");
        let (merged, faults) = store.load_merged();
        assert!(faults.is_empty(), "shard faults at K={k}: {faults:?}");
        assert_eq!(
            merged.to_json().pretty(),
            bytes8,
            "K={k} round trip changed merged bytes"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- warm rerun: >= 90% class hit rate, db bytes unchanged ----
    let warm = fleet_compile(&jobs, &cfg(8), &mut db8);
    assert!(
        warm.stats.hit_rate >= 0.9,
        "warm fleet hit rate {:.2} < 0.9",
        warm.stats.hit_rate
    );
    assert_eq!(
        db8.to_json().pretty(),
        bytes8,
        "warm rerun changed merged db bytes"
    );
    println!(
        "warm rerun: hit rate {:.0}%, {} ledger tasks",
        warm.stats.hit_rate * 100.0,
        warm.stats.ledger_tasks
    );

    // ---- wall-clock gate, proportional to the host ----
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let speedup = serial_secs / fleet8_secs.max(1e-9);
    let floor = if cores >= 8 {
        Some(2.0)
    } else if cores >= 4 {
        Some(1.3)
    } else {
        None
    };
    println!(
        "speedup: {speedup:.2}x over serial on {cores} core(s) \
         (floor {})",
        floor.map_or("none (report-only)".to_string(), |f| format!("{f}x"))
    );
    if let Some(f) = floor {
        assert!(
            speedup >= f,
            "fleet w8 {fleet8_secs:.2}s vs serial {serial_secs:.2}s: \
             {speedup:.2}x < required {f}x on {cores} cores"
        );
    }

    let dedup_ratio =
        out8.stats.classes as f64 / out8.stats.ledger_tasks.max(1) as f64;
    let record = obj(vec![
        ("bench", s("fleet_compile")),
        ("quick", num(if quick { 1.0 } else { 0.0 })),
        ("models", s("all/small")),
        ("jobs", num(jobs.len() as f64)),
        ("budget", num(budget as f64)),
        ("cores", num(cores as f64)),
        ("serial_secs", num(serial_secs)),
        ("fleet_w1_secs", num(fleet1_secs)),
        ("fleet_w8_secs", num(fleet8_secs)),
        ("speedup_w8_vs_serial", num(speedup)),
        ("speedup_floor", num(floor.unwrap_or(0.0))),
        ("classes", num(out8.stats.classes as f64)),
        ("ledger_tasks", num(out8.stats.ledger_tasks as f64)),
        ("dedup_ratio", num(dedup_ratio)),
        ("ambiguous", num(out8.stats.ambiguous as f64)),
        ("cold_hit_rate", num(out8.stats.hit_rate)),
        ("warm_hit_rate", num(warm.stats.hit_rate)),
        ("db_entries", num(db8.len() as f64)),
    ]);
    std::fs::write("BENCH_fleet.json", record.pretty())
        .expect("write BENCH_fleet.json");
    println!("wrote BENCH_fleet.json");
}

//! Fig. 12 — the "emerging new networks": Bert-tiny (seq 128, both
//! devices) and MobileViT (224, Kirin only — the paper skips MVT on the
//! resource-limited 810).

use ago::device::DeviceProfile;
use ago::experiments::{bench_budget, e2e_rows, render_e2e};
use ago::models::{InputShape, ModelId};

fn main() {
    let budget = bench_budget();
    println!("budget = {budget} evals\n");
    for dev in [DeviceProfile::qsd810(), DeviceProfile::kirin990()] {
        let mut models = vec![ModelId::Bt];
        if dev.name == "kirin990" {
            models.push(ModelId::Mvt);
        }
        let rows = e2e_rows(&dev, budget, &models, &[InputShape::Large]);
        print!("{}", render_e2e(&rows, dev.name));
        println!();
    }
    println!(
        "paper (Fig. 12): +38.2% over Torch Mobile / +20.5% over Ansor on \
         BT; +34.3% / +29.1% on MVT"
    );
}

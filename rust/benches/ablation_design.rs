//! Design-choice ablations DESIGN.md calls out (beyond the paper's own
//! Fig. 13): the reformer's budget split fraction, and cost-model-vs-
//! cache-simulator agreement on the fusion saving.

use ago::device::DeviceProfile;
use ago::experiments::micro_subgraphs;
use ago::reformer::{tune_with_reformer, ReformerConfig};
use ago::simulator::{trace, Hierarchy};
use ago::tuner::search::SearchConfig;
use ago::util::benchkit::Table;
use ago::util::stats::geomean;

fn main() {
    // --- reformer split-fraction sweep (budget-starved regime) ---------
    let dev = DeviceProfile::qsd810();
    let budget = 120;
    println!("== reformer split_fraction sweep (budget {budget}) ==");
    let mut t = Table::new(&["split", "geomean latency (4 subgraphs, ms)"]);
    for frac in [0.25, 0.5, 0.75] {
        let mut lats = Vec::new();
        for ms in micro_subgraphs(4) {
            let mut per_seed = Vec::new();
            for seed in [1u64, 2, 3] {
                let cfg = ReformerConfig {
                    split_fraction: frac,
                    search: SearchConfig {
                        budget,
                        stabilize_window: budget,
                        seed,
                        ..Default::default()
                    },
                    enabled: true,
                    // legacy 24/16 floors — the sweep predates them
                    ..Default::default()
                };
                let r = tune_with_reformer(&ms.graph, &ms.view, &dev, &cfg);
                per_seed.push(r.best_latency * 1e3);
            }
            lats.push(geomean(&per_seed));
        }
        t.row(vec![format!("{frac:.2}"), format!("{:.4}", geomean(&lats))]);
    }
    t.print();

    // --- cost model vs cache simulator: fusion saving agreement --------
    println!("\n== trace-driven simulator: intermediate round-trip ==");
    let mut t = Table::new(&[
        "intermediate", "unfused DRAM lines", "fused DRAM lines", "saving",
    ]);
    for elems in [64 * 1024, 512 * 1024, 4 * 1024 * 1024] {
        let mut unfused = Hierarchy::for_device(&dev);
        trace::producer_consumer(&mut unfused, 0, elems);
        let mut fused = Hierarchy::for_device(&dev);
        trace::fused_producer_consumer(&mut fused, 0, elems, 2048);
        t.row(vec![
            format!("{} KB", elems * 4 / 1024),
            unfused.dram_accesses.to_string(),
            fused.dram_accesses.to_string(),
            format!(
                "{:.1}x",
                unfused.dram_accesses.max(1) as f64
                    / fused.dram_accesses.max(1) as f64
            ),
        ]);
    }
    t.print();
    println!(
        "\n(the cost model's fusion term prices exactly this saving; see \
         costmodel tests for the cross-check)"
    );
}
